"""Stage 1: mod-raise — the only genuinely new engine op.

The arithmetic lives where all served limb arithmetic lives:
`core.heaan.mod_raise_poly` / `he_mod_raise` (the batched centered
sign-extended lift) and `hserve.engine.make_mod_raise_step` (the jit-once
serving step). This module is the boot-pipeline view of it: the
`CircuitOp` constructor and the raise-target policy.

Why the lift is what it is: q = 2^logq, so a coefficient c ∈ [0, q) is
the two's-complement image of the centered integer ĉ ∈ [−q/2, q/2). The
raise re-embeds ĉ into [0, q') by sign-extending the limb array — an
EXACT operation on the decoded view. Decryption at q' then yields
t = m + e + q·I(X) with ‖I‖_∞ ≤ (h+1)/2 + 1 (bx plus h signed rotations
of ax, each bounded by q/2, plus message/noise slack) — the q·I term is
what EvalMod removes.
"""

from __future__ import annotations

from repro.core.params import HEParams
from repro.hserve.circuit import CircuitOp

__all__ = ["mod_raise_op", "raise_target", "interval_bound"]


def raise_target(params: HEParams, logq_in: int) -> int:
    """Where mod-raise lifts to: the top of the modulus chain. The
    bootstrap wants every level it can get — the pipeline consumes
    7 + r levels and whatever is left is the refreshed depth."""
    if not 0 < logq_in < params.logQ:
        raise ValueError(
            f"cannot mod-raise from logq={logq_in} "
            f"(need 0 < logq_in < logQ={params.logQ})")
    return params.logQ


def interval_bound(params: HEParams, msg_bound: float) -> float:
    """Bound on |t|/q after the raise (in units of q): bx contributes
    q/2, ax·s contributes h·q/2 (h signed rotations), plus the message
    and noise slack — the I(X) interval EvalMod's sine must cover."""
    return (params.h + 1) / 2.0 + 1.0 + msg_bound


def mod_raise_op(arg, logq2: int) -> CircuitOp:
    """The mod-raise circuit node (arg: input name or node index)."""
    return CircuitOp("mod_raise", (arg,), logq2=logq2)
