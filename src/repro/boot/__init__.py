"""repro.boot — batched CKKS bootstrapping as a first-class circuit.

Bootstrapping (HEAAN's Cheon-Han-Kim-Kim-Song pipeline; the paper's
§III-A names running out of modulus as THE depth limit this removes)
refreshes a level-exhausted ciphertext in four stages:

    mod-raise      →  lift the mod-q limbs into a wider modulus q'
                      (exact centered lift; introduces q·I(X))
    CoeffToSlot    →  homomorphic inverse embedding: slots now hold the
                      raw polynomial coefficients t = m + q·I (as
                      complex pairs), a BSGS diagonal linear transform
    EvalMod        →  approximate t mod q via the scaled sine
                      (complex-exponential Taylor + repeated squaring),
                      removing the q·I term
    SlotToCoeff    →  homomorphic embedding back to coefficient form —
                      the refreshed ciphertext, at a HIGHER level

The whole pipeline is expressed as a validated `CircuitOp` DAG
(:func:`repro.boot.pipeline.bootstrap_circuit`) that rides the existing
serving stack: every stage batches through `HEServer.submit_circuit`,
co-batches ACROSS concurrent bootstraps via the circuit scheduler, and
ships its CoeffToSlot/SlotToCoeff diagonals through the (hash, level)
plaintext cache — hash-only on every repeat bootstrap.

Unlike every other served circuit (pinned bitwise against the core
references), bootstrap is APPROXIMATE by construction: its contract is
the documented slot-error bound (`BootstrapPlan.error_bound`,
docs/BOOTSTRAP.md), property-tested over seeded random messages.
"""

from repro.boot.evalmod import eval_mod, exp_taylor_coeffs, poly_eval
from repro.boot.linear import (bsgs_matvec, coeff_to_slot_matrix,
                               slot_to_coeff_matrix)
from repro.boot.modraise import mod_raise_op, raise_target
from repro.boot.pipeline import (BOOT_STAGES, BootConfig, BootstrapPlan,
                                 boot_params, bootstrap_circuit)

__all__ = [
    "BOOT_STAGES", "BootConfig", "BootstrapPlan", "boot_params",
    "bootstrap_circuit", "bsgs_matvec", "coeff_to_slot_matrix",
    "slot_to_coeff_matrix", "eval_mod", "exp_taylor_coeffs",
    "poly_eval", "mod_raise_op", "raise_target",
]
