"""The bootstrap pipeline: four stages, one validated CircuitOp DAG.

:func:`bootstrap_circuit` builds the whole pipeline as a plan the
serving stack treats like any other circuit — `HEServer.submit_circuit`
walks it, nodes co-batch across concurrent bootstraps via the circuit
scheduler, diagonals ride the plaintext cache. Construction is
compile-pass-driven: each post-raise stage is TRACED through the client
handle API against a sentinel session (metadata-only input), lowered
with `compile_handle` (auto level alignment, CSE, plain-operand
hashing), and the three lowered stages are stitched behind the
`mod_raise` node with argument renumbering. The stitched DAG is then
re-validated end-to-end through the shared dataflow engine.

Level budget (with Taylor degree d and r squarings):

    1 (CtS) + 1 (arg) + ⌈log₂(d+1)⌉ (Taylor) + r (squarings)
    + 1 (Im) + 1 (StC)   —   11 levels at the d=7, r=4 default

so the refreshed ciphertext lands at logQ − 11·logp: the reference
small-param config (:func:`boot_params`: logN=4, logQ=336, logp=24,
h=2) leaves 3 fresh levels — enough for the acceptance gate's two
further muls.

Error contract (docs/BOOTSTRAP.md): bootstrap is approximate. For
inputs at q_s = 1 (logq_in == logp — where auto-insertion fires) with
per-slot message magnitude ≤ `msg_bound`, the decrypted slot error is
bounded by :meth:`BootstrapPlan.error_bound` — the sine-vs-identity
cubic term + the Taylor remainder (amplified linearly by the
squarings) + fixed-point slack, times a documented safety factor of 4.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.boot.evalmod import eval_mod
from repro.boot.linear import (bsgs_matvec, coeff_to_slot_matrix,
                               default_giant_step, slot_to_coeff_matrix)
from repro.boot.modraise import interval_bound, raise_target
from repro.core.cipher import Ciphertext
from repro.core.params import HEParams
from repro.hserve.circuit import CircuitOp

__all__ = ["BOOT_STAGES", "BootConfig", "BootstrapPlan", "boot_params",
           "bootstrap_circuit", "DEFAULT_MSG_BOUND"]

BOOT_STAGES = ("mod_raise", "coeff_to_slot", "eval_mod",
               "slot_to_coeff")

# the documented per-slot message-magnitude contract: the cubic
# sine deviation grows as |z|³, so bootstrap inputs keep |z| small
DEFAULT_MSG_BOUND = 2.0 ** -5


def boot_params(logN: int = 4, logQ: int = 336, logp: int = 24,
                beta_bits: int = 32) -> HEParams:
    """The reference small-param bootstrap config (NOT secure): h = 2
    keeps the mod-raise interval |I| ≤ 2.5 so r = 4 squarings cover it,
    and L = 14 leaves 3 levels after the 11 the pipeline consumes."""
    return HEParams(logN=logN, logQ=logQ, logp=logp, log_delta=logp,
                    beta_bits=beta_bits, h=2)


@dataclasses.dataclass(frozen=True)
class BootConfig:
    """Pipeline knobs.

    degree: Taylor degree for exp(iθ/2^r).
    r:      squaring count; 0 → smallest r with θ_max/2^r ≤ 1.
    giant_step: BSGS baby count for the linear stages (0 → ≈√n).
    logq_top: raise target (0 → params.logQ).
    """

    degree: int = 7
    r: int = 0
    giant_step: int = 0
    logq_top: int = 0


@dataclasses.dataclass
class BootstrapPlan:
    """One ready-to-submit bootstrap circuit + its contract metadata.

    ops/meta are the stitched, validated DAG over the single input
    `in_name`; stages labels each node with its pipeline stage (the
    obs plane's boot.* span attribution reads it); requires /
    plain_registers / pt_bounds mirror `CompiledCircuit`'s fields so
    sessions provision keys and the analyzer bounds noise the same way
    as for any compiled trace.
    """

    ops: List[CircuitOp]
    meta: List[Tuple[int, int]]
    stages: List[str]
    requires: Set[Tuple]
    plain_registers: Set[Tuple[str, int]]
    pt_bounds: Dict[int, float]
    params: HEParams
    config: BootConfig
    logq_in: int
    logp: int
    n_slots: int
    msg_bound: float
    in_name: str = "x"

    @property
    def out_logq(self) -> int:
        return self.meta[-1][0]

    @property
    def out_logp(self) -> int:
        return self.meta[-1][1]

    @property
    def levels_gained(self) -> int:
        return (self.out_logq - self.logq_in) // self.params.logp

    @property
    def r(self) -> int:
        return self.config.r or _auto_r(self.params, self.msg_bound)

    def error_bound(self, msg_bound: Optional[float] = None) -> float:
        """The documented |decrypted slot − message| bound (absolute,
        per slot) for inputs within `msg_bound`. Three terms, each from
        the construction, times a safety factor of 4:

        - cubic sine-vs-identity deviation (2π/q_s)²·mb³/6 — the
          dominant term at the contract boundary;
        - Taylor remainder of exp at |w| ≤ θ_max/2^r, amplified
          linearly by the r squarings (d exp(w)^(2^r) ≈ 2^r on |v|=1);
        - fixed-point slack: encode/rescale rounding across the
          pipeline's ~N-coefficient working set at scale 2^−logp.
        """
        mb = self.msg_bound if msg_bound is None else msg_bound
        p, cfg = self.params, self.config
        q_s = 2.0 ** (self.logq_in - self.logp)
        theta_max = 2.0 * math.pi * interval_bound(p, mb)
        w_max = theta_max / 2.0 ** self.r
        eps_taylor = w_max ** (cfg.degree + 1) \
            / math.factorial(cfg.degree + 1)
        cubic = (2.0 * math.pi / q_s) ** 2 * mb ** 3 / 6.0
        taylor = (q_s / (2.0 * math.pi)) * 2.0 ** self.r * eps_taylor
        fixed = p.N * 2.0 ** -self.logp
        return 4.0 * (cubic + taylor + fixed)

    def resolved_ops(self) -> List[CircuitOp]:
        """ops with every hash-only plaintext operand backfilled from
        its materialized first occurrence — for the cacheless reference
        path (`execute_circuit_reference`); `submit_circuit` resolves
        the same way through the server's plaintext cache."""
        def in_lq(a):
            return self.logq_in if isinstance(a, str) else self.meta[a][0]
        store: Dict[Tuple[str, int], object] = {}
        out = []
        for node in self.ops:
            if node.pt_hash is not None:
                key = (node.pt_hash, in_lq(node.args[0]))
                if node.pt is None:
                    node = dataclasses.replace(node, pt=store[key])
                else:
                    store[key] = node.pt
            out.append(node)
        return out


def _auto_r(params: HEParams, msg_bound: float) -> int:
    """Smallest squaring count putting the Taylor argument inside the
    unit disc: θ_max/2^r ≤ 1."""
    theta_max = 2.0 * math.pi * interval_bound(params, msg_bound)
    return max(1, math.ceil(math.log2(theta_max)))


class _Sentinel:
    """Trace-only session object: handles check identity, nothing else."""

    def __repr__(self):                        # pragma: no cover
        return "<boot trace session>"


def _stage_input(session, params: HEParams, logq: int, logp: int,
                 n_slots: int):
    """A metadata-only input handle for one stage's trace (the arrays
    are never read — stitching replaces the input with a node ref)."""
    from repro.client.handles import CipherHandle
    dt = np.uint32 if params.beta_bits == 32 else np.uint64
    z = np.zeros((params.N, params.qlimbs(logq)), dt)
    return CipherHandle(session, "input",
                        ct=Ciphertext(ax=z, bx=z, logq=logq, logp=logp,
                                      n_slots=n_slots))


def bootstrap_circuit(params: HEParams, *, logq_in: int,
                      logp: Optional[int] = None,
                      n_slots: Optional[int] = None,
                      config: Optional[BootConfig] = None,
                      msg_bound: float = DEFAULT_MSG_BOUND,
                      plain_lookup: Optional[Callable[[str, int], bool]]
                      = None) -> BootstrapPlan:
    """Build the four-stage bootstrap plan for one input shape.

    logq_in/logp: the exhausted ciphertext's position (logq_in == logp
        — q_s = 1 — is the contract point auto-insertion targets;
        larger q_s is allowed and widens the error bound by q_s²).
    n_slots: must be the FULL slot count N/2 (see `repro.boot.linear`).
    plain_lookup: the server's plaintext-cache membership test —
        matching diagonals ship hash-only (repeat bootstraps encode
        nothing).

    Raises `repro.analysis.dataflow.CircuitError` when the modulus
    chain cannot fit the pipeline (logQ < (7 + r + L_in)·logp), and
    ValueError on sparse slots.
    """
    from repro.client.compile import compile_handle

    logp = params.logp if logp is None else logp
    n = params.n_slots_max if n_slots is None else n_slots
    if n != params.n_slots_max:
        raise ValueError(
            f"bootstrap needs full slots (n = N/2 = "
            f"{params.n_slots_max}, got {n}): with gap > 1 the unused "
            f"coefficients carry mod-raise junk that ring muls would "
            f"mix into the message")
    cfg = config or BootConfig()
    r = cfg.r or _auto_r(params, msg_bound)
    theta_max = 2.0 * math.pi * interval_bound(params, msg_bound)
    if theta_max / 2.0 ** r > 1.1:
        raise ValueError(
            f"r={r} squarings leave the Taylor argument at "
            f"{theta_max / 2.0 ** r:.2f} > 1.1 (h={params.h} is too "
            f"heavy for this r; raise r or use a lighter boot key)")
    cfg = dataclasses.replace(cfg, r=r)
    logq_top = cfg.logq_top or raise_target(params, logq_in)
    g = cfg.giant_step or default_giant_step(n)

    Ei = coeff_to_slot_matrix(n, params.N)
    E = slot_to_coeff_matrix(n, params.N)

    # trace + lower each post-raise stage separately: exact per-stage
    # node attribution (the obs plane's boot.* spans) with the compile
    # pass still owning levels/CSE/plain hashing inside each stage
    regs: Set[Tuple[str, int]] = set()

    def lookup(h: str, lq: int) -> bool:
        return (h, lq) in regs or (plain_lookup is not None
                                   and plain_lookup(h, lq))

    session = _Sentinel()
    stage_ccs = []
    in_lq, in_lp = logq_top, logp
    builders = (
        ("coeff_to_slot", lambda x: bsgs_matvec(x, Ei, giant_step=g)),
        ("eval_mod", lambda x: eval_mod(
            x, q_s_bits=logq_in - logp, degree=cfg.degree, r=cfg.r)),
        ("slot_to_coeff", lambda x: bsgs_matvec(x, E, giant_step=g)),
    )
    for name, build in builders:
        x = _stage_input(session, params, in_lq, in_lp, n)
        cc = compile_handle(build(x), params, plain_lookup=lookup)
        regs |= cc.plain_registers
        stage_ccs.append((name, cc))
        in_lq, in_lp = cc.out_logq, cc.out_logp

    # stitch: [mod_raise] ++ stages, renumbering each stage's local
    # refs (+offset) and grafting its single input onto the previous
    # stage's output node
    in_name = "x"
    ops: List[CircuitOp] = [CircuitOp("mod_raise", (in_name,),
                                      logq2=logq_top)]
    stages: List[str] = ["mod_raise"]
    requires: Set[Tuple] = set()
    pt_bounds: Dict[int, float] = {}
    prev_out = 0
    for name, cc in stage_ccs:
        off = len(ops)
        for node in cc.ops:
            args = tuple(prev_out if isinstance(a, str) else a + off
                         for a in node.args)
            ops.append(dataclasses.replace(node, args=args))
            stages.append(name)
        for i, b in cc.pt_bounds.items():
            pt_bounds[i + off] = b
        requires |= cc.requires
        prev_out = len(ops) - 1

    # end-to-end re-validation through the shared dataflow engine (the
    # level schedule the scheduler and the server will both see)
    from repro.analysis.dataflow import propagate
    meta = propagate(ops, {in_name: (logq_in, logp)}, params)
    return BootstrapPlan(ops=ops, meta=meta, stages=stages,
                         requires=requires, plain_registers=regs,
                         pt_bounds=pt_bounds, params=params, config=cfg,
                         logq_in=logq_in, logp=logp, n_slots=n,
                         msg_bound=msg_bound, in_name=in_name)
