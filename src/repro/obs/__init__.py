"""repro.obs — span tracing, stage attribution, and serving telemetry.

The paper's method IS measurement: Fig. 3 attributes HE Mul wall time
to CRT/NTT/modmul/iCRT, and every optimization in the paper follows
from that attribution. This package gives the serving runtime the same
lens, three surfaces deep:

  - :class:`Tracer` (`trace.py`) — nested spans with injectable clocks,
    exported as Chrome trace-event JSON (Perfetto / chrome://tracing).
    Request lifecycle (submit → enqueue → bucket_wait → flush →
    batch_assemble → dispatch → device_wall → complete), engine-side
    spans (table-slice fetch, H2D transfer, warm compiles), and — under
    `--profile-stages` — per-stage Fig. 3 events.
  - :class:`MetricsRegistry` (`registry.py`) — counters, gauges, and
    bounded histograms plus pull-based sources (ServeMetrics,
    TableCache, CircuitScheduler, HESession all publish), snapshot as
    JSON on demand and embedded in `runtime.monitor.Heartbeat`
    payloads — the health channel the multi-host tier will consume.
  - :class:`StageTimer` (`stages.py`) — the `make_stage_fns` hook that
    buckets mul wall time into the paper's CRT / NTT / modmul / iCRT
    taxonomy with per-stage block_until_ready fencing.

`python -m repro.obs report trace.json` prints the attribution table
and the queue-wait vs device-wall latency decomposition (`report.py`).

See docs/OBSERVABILITY.md for the span taxonomy and naming contract.
"""

from repro.obs.registry import MetricsRegistry, merge_snapshots
from repro.obs.stages import STAGES, StageTimer
from repro.obs.stats import Reservoir
from repro.obs.trace import Span, Tracer

__all__ = ["MetricsRegistry", "merge_snapshots", "Reservoir", "Span",
           "StageTimer", "STAGES", "Tracer"]
