"""CLI: `python -m repro.obs report trace.json [--json]`."""

from __future__ import annotations

import argparse
import json
import sys

from repro.obs.report import analyze, format_report, load_events


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="serving-trace analysis (Fig. 3 attribution + "
                    "latency decomposition)")
    sub = ap.add_subparsers(dest="cmd", required=True)
    rep = sub.add_parser("report", help="summarize a trace.json written "
                                        "by serve --he --trace")
    rep.add_argument("trace", help="Chrome trace-event JSON file")
    rep.add_argument("--json", action="store_true",
                     help="emit the aggregation as JSON instead of text")
    args = ap.parse_args(argv)
    a = analyze(load_events(args.trace))
    if args.json:
        json.dump(a, sys.stdout, indent=2)
        print()
    else:
        print(format_report(a))
    return 0


if __name__ == "__main__":
    sys.exit(main())
