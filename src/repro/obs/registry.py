"""Unified metrics plane: counters, gauges, bounded histograms, sources.

One :class:`MetricsRegistry` per server process. Two publication
styles, both snapshot into a single JSON document:

  - **First-class instruments** — `counter(name)` / `gauge(name)` /
    `histogram(name)` return live handles a component increments on its
    own hot path. Histograms are :class:`~repro.obs.stats.Reservoir`
    backed, so a registry never grows without bound.
  - **Pull sources** — `add_source(name, fn)` registers a zero-arg
    callable returning a dict; `snapshot()` calls it. This is how the
    existing stats surfaces (ServeMetrics.summary, TableCache.stats,
    CircuitScheduler.stats, HESession) publish without restructuring —
    the registry pulls their current view instead of them pushing every
    update.

Naming scheme (docs/OBSERVABILITY.md): dotted lowercase,
`<component>.<noun>[.<unit>]` — e.g. `serve.polls`, `serve.batch.wall_s`,
`client.runs`. Source names are bare component names ("serve", "cache",
"scheduler") and own a sub-document each.

`snapshot()` output feeds three consumers: `serve --he --metrics PATH`,
`runtime.monitor.Heartbeat(metrics=...)` payload embedding (the health
channel the multi-host tier consumes), and the OBS_SCHEMA check in
tools/check_docs.py.
"""

from __future__ import annotations

from typing import Callable, Dict

from repro.obs.stats import Reservoir

__all__ = ["Counter", "Gauge", "MetricsRegistry", "merge_snapshots"]


class Counter:
    """Monotonic count. `inc()` on the hot path, value in snapshots."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-set value (queue depth, inflight batches, ...)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, x: float) -> None:
        self.value = float(x)


class MetricsRegistry:
    def __init__(self, histogram_capacity: int = 4096):
        self._histogram_capacity = histogram_capacity
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Reservoir] = {}
        self._sources: Dict[str, Callable[[], dict]] = {}

    # ---- instruments ------------------------------------------------------

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str) -> Reservoir:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Reservoir(
                capacity=self._histogram_capacity)
        return h

    # ---- pull sources -----------------------------------------------------

    def add_source(self, name: str, fn: Callable[[], dict]) -> None:
        """Register (or replace) a snapshot contributor. Replacement is
        deliberate: `HEServer.reset_metrics` swaps in a fresh
        ServeMetrics and re-registers it under the same name."""
        self._sources[name] = fn

    def remove_source(self, name: str) -> None:
        self._sources.pop(name, None)

    # ---- export -----------------------------------------------------------

    def snapshot(self) -> dict:
        """One JSON document: instruments + every source's current view.
        A source that raises poisons health reporting exactly when it is
        needed most, so failures are captured inline instead."""
        out = {
            "counters": {k: c.value
                         for k, c in sorted(self._counters.items())},
            "gauges": {k: g.value
                       for k, g in sorted(self._gauges.items())},
            "histograms": {k: h.summary()
                           for k, h in sorted(self._histograms.items())},
        }
        for name, fn in sorted(self._sources.items()):
            try:
                out[name] = fn()
            except Exception as e:          # noqa: BLE001 — see docstring
                out[name] = {"error": f"{type(e).__name__}: {e}"}
        return out


def merge_snapshots(snaps: Dict[str, dict]) -> dict:
    """Merge per-publisher registry snapshots into one document with
    every label namespaced by its publisher id.

    Multi-host serving has N workers each publishing its own registry
    (every worker counts "worker.batches", sources its own "engine"
    view, ...). Naively dict-merging those snapshots silently keeps one
    publisher's value per colliding key; prefixing every instrument key
    and source name with ``"<publisher>."`` makes collisions impossible
    by construction while keeping the merged document's top-level shape
    (counters/gauges/histograms + source sub-docs) identical to a
    single registry's — heartbeat consumers parse either.
    """
    out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
    for pub, snap in sorted(snaps.items()):
        for section in ("counters", "gauges", "histograms"):
            for k, v in (snap.get(section) or {}).items():
                out[section][f"{pub}.{k}"] = v
        for name, sub in snap.items():
            if name in ("counters", "gauges", "histograms"):
                continue
            out[f"{pub}.{name}"] = sub
    return out
