"""Span tracing with Chrome trace-event export (Perfetto-loadable).

One :class:`Tracer` instance is threaded through the serving stack
(HEServer → OpEngine → TableCache → StageTimer) and records everything
as complete events — ph "X" with explicit pid/tid/ts/dur/name/cat —
because a single uniform event shape keeps downstream consumers
(tools/check_docs.py's OBS_SCHEMA, repro.obs.report, Perfetto) trivial:
instants are just zero-duration spans. Timestamps come from an
injectable clock (same convention as `hserve.queue.RequestQueue`), so
tests drive the tracer with a fake clock and assert exact orderings.

Lanes: trace-event `tid` must be an integer, but call sites think in
names ("requests", "engine", "stage"). The tracer interns each lane
name to a small int and emits one "M"/thread_name metadata record per
lane so Perfetto shows the name. Metadata records carry the same
ts/dur/cat keys as everything else — one schema, no special cases.

The DISABLED tracer is free: `span()`/`event()`/`instant()` return a
shared no-op singleton and append nothing, so `serve --he` without
`--trace` allocates zero objects per request on the hot path (pinned by
tests/test_obs.py).
"""

from __future__ import annotations

import json
import time
from typing import Callable, Dict, List, Optional

__all__ = ["Span", "Tracer"]

# Metadata records reuse the full event schema (ts/dur keys and all) so
# every element of traceEvents validates against the same OBS_SCHEMA.
_EVENT_KEYS = ("pid", "tid", "ts", "dur", "name", "cat", "ph")


class Span:
    """An open span: entered at construction time, closed on `end()` /
    context exit. The no-op singleton (`tracer disabled`) shares this
    class with `_live=False` so the hot path has no isinstance checks."""

    __slots__ = ("_tracer", "name", "cat", "lane", "args", "_t0", "_live")

    def __init__(self, tracer: Optional["Tracer"], name: str, cat: str,
                 lane: str, args: Optional[dict], t0: float, live: bool):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.lane = lane
        self.args = args
        self._t0 = t0
        self._live = live

    def end(self, **extra_args) -> None:
        if not self._live:
            return
        self._live = False
        tr = self._tracer
        args = self.args
        if extra_args:
            args = {**(args or {}), **extra_args}
        tr.event(self.name, cat=self.cat, lane=self.lane, ts=self._t0,
                 dur=tr.clock() - self._t0, args=args)

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc) -> None:
        self.end()


_NULL_SPAN = Span(None, "", "", "", None, 0.0, live=False)


class Tracer:
    """Record spans/instants; export Chrome trace-event JSON.

    enabled: when False every recording call is a no-op returning a
        shared singleton — the zero-cost default for serving.
    clock: seconds-valued monotonic callable (injectable for tests;
        HEServer passes its own clock so queue timestamps and trace
        timestamps share one axis).
    pid: the trace-event process id (one server = one pid).
    max_events: hard cap on retained events — a tracer left on for a
        week must not become its own unbounded-memory bug. Overflow
        drops new events and counts them (`dropped`).
    """

    def __init__(self, enabled: bool = True,
                 clock: Optional[Callable[[], float]] = None,
                 pid: int = 1, max_events: int = 1_000_000):
        self.enabled = enabled
        self.clock = clock if clock is not None else time.perf_counter
        self.pid = pid
        self.max_events = max_events
        self.dropped = 0
        self._events: List[dict] = []
        self._lanes: Dict[str, int] = {}
        self._t0 = self.clock()

    # ---- recording --------------------------------------------------------

    def _lane_tid(self, lane: str) -> int:
        tid = self._lanes.get(lane)
        if tid is None:
            tid = self._lanes[lane] = len(self._lanes)
            # thread_name metadata so Perfetto labels the lane; carries
            # the full event key set (see module docstring).
            self._events.append({
                "pid": self.pid, "tid": tid, "ts": 0.0, "dur": 0.0,
                "name": "thread_name", "cat": "__metadata", "ph": "M",
                "args": {"name": lane},
            })
        return tid

    def event(self, name: str, *, cat: str, lane: str, ts: float,
              dur: float = 0.0, args: Optional[dict] = None) -> None:
        """Append one complete event with EXPLICIT clock-domain
        timestamps (seconds on this tracer's clock). The server emits
        lifecycle events from queue-recorded times (`t_submit`) rather
        than wrapping code in spans — that needs the explicit form."""
        if not self.enabled:
            return
        if len(self._events) >= self.max_events:
            self.dropped += 1
            return
        ev = {
            "pid": self.pid, "tid": self._lane_tid(lane),
            "ts": (ts - self._t0) * 1e6,        # trace-event µs
            "dur": dur * 1e6,
            "name": name, "cat": cat, "ph": "X",
        }
        if args is not None:
            ev["args"] = args
        self._events.append(ev)

    def span(self, name: str, *, cat: str, lane: str,
             args: Optional[dict] = None) -> Span:
        """Open a span at now(); closes (and records) on end()/exit."""
        if not self.enabled:
            return _NULL_SPAN
        return Span(self, name, cat, lane, args, self.clock(), live=True)

    def instant(self, name: str, *, cat: str, lane: str,
                args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        self.event(name, cat=cat, lane=lane, ts=self.clock(), args=args)

    # ---- export -----------------------------------------------------------

    def __len__(self) -> int:
        return len(self._events)

    @property
    def events(self) -> List[dict]:
        return self._events

    def to_chrome(self) -> dict:
        """The Chrome trace-event container Perfetto /
        chrome://tracing load directly."""
        return {"traceEvents": list(self._events),
                "displayTimeUnit": "ms"}

    def write(self, path: str) -> int:
        """Write trace JSON; returns the event count (metadata
        included)."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return len(self._events)

    def clear(self) -> None:
        """Drop recorded events and lane metadata; keep the clock/t0 so
        timestamps stay on one axis across measurement windows."""
        self._events = []
        self._lanes = {}
        self.dropped = 0
