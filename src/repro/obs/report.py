"""Offline trace analysis: Fig. 3 attribution + latency decomposition.

`python -m repro.obs report trace.json` reads a Chrome trace-event file
written by `serve --he --trace` and prints:

  - per-op / per-stage attribution (cat="stage" events): wall seconds
    in each of the paper's CRT / NTT / modmul / iCRT buckets, their
    fraction of the op's bucketed total, and the Fig. 2 region split —
    the table the paper's Fig. 3 is;
  - a queue-wait vs device-wall latency decomposition (lifecycle
    events): how much of each op's request latency is spent waiting in
    a bucket (the batching/SLO trade) vs on the device (the compute
    floor) — the serving-side split HEAX argues pipeline occupancy
    from.

Stdlib-only on purpose: the report runs anywhere the trace file lands,
no jax/numpy needed.
"""

from __future__ import annotations

import json
from collections import defaultdict
from typing import Dict, List

from repro.obs.stages import STAGES

__all__ = ["load_events", "analyze", "format_report"]


def load_events(path: str) -> List[dict]:
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents", doc if isinstance(doc, list) else [])
    return [e for e in events if e.get("ph") == "X"]


def analyze(events: List[dict]) -> dict:
    """Aggregate a trace into the report's two tables (seconds)."""
    stage_s: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {s: 0.0 for s in STAGES})
    region_s: Dict[str, Dict[str, float]] = defaultdict(
        lambda: defaultdict(float))
    wait_s: Dict[str, float] = defaultdict(float)
    wait_n: Dict[str, int] = defaultdict(int)
    dev_s: Dict[str, float] = defaultdict(float)
    dev_batches: Dict[str, int] = defaultdict(int)
    complete_n: Dict[str, int] = defaultdict(int)
    latency_s: Dict[str, float] = defaultdict(float)
    for e in events:
        cat = e.get("cat")
        op = (e.get("args") or {}).get("op", "?")
        dur = e.get("dur", 0.0) / 1e6
        name = e.get("name")
        if cat == "stage":
            if name in STAGES:
                stage_s[op][name] += dur
            else:
                region_s[op][name] += dur
        elif cat == "lifecycle":
            if name == "bucket_wait":
                wait_s[op] += dur
                wait_n[op] += 1
            elif name == "device_wall":
                dev_s[op] += dur
                dev_batches[op] += 1
            elif name == "complete":
                complete_n[op] += 1
                latency_s[op] += (e.get("args") or {}).get("latency_s",
                                                           0.0)
    return {
        "stages": {op: dict(v) for op, v in stage_s.items()},
        "regions": {op: dict(v) for op, v in region_s.items()},
        "queue_wait": {op: {"total_s": wait_s[op], "n": wait_n[op]}
                       for op in wait_n},
        "device_wall": {op: {"total_s": dev_s[op],
                             "batches": dev_batches[op]}
                        for op in dev_batches},
        "complete": {op: {"n": complete_n[op],
                          "latency_total_s": latency_s[op]}
                     for op in complete_n},
    }


def _fmt_ms(s: float) -> str:
    return f"{1e3 * s:10.2f}"


def format_report(a: dict) -> str:
    lines: List[str] = []
    if a["stages"]:
        lines.append("Fig. 3 stage attribution (ms, per op kind)")
        hdr = f"{'op':>10} " + " ".join(f"{s:>10}" for s in STAGES) \
            + f" {'sum':>10}"
        lines.append(hdr)
        for op in sorted(a["stages"]):
            row = a["stages"][op]
            tot = sum(row.values())
            lines.append(f"{op:>10} "
                         + " ".join(_fmt_ms(row[s]) for s in STAGES)
                         + f" {_fmt_ms(tot)}")
            if tot > 0:
                lines.append(f"{'':>10} "
                             + " ".join(f"{row[s] / tot:>9.1%} "
                                        for s in STAGES))
        for op in sorted(a["regions"]):
            reg = a["regions"][op]
            parts = ", ".join(f"{k}={1e3 * v:.2f}ms"
                              for k, v in sorted(reg.items()))
            lines.append(f"{op:>10} regions: {parts}")
        lines.append("")
    else:
        lines.append("no stage events (run serve with --profile-stages "
                     "for Fig. 3 attribution)")
        lines.append("")
    lines.append("latency decomposition: queue wait vs device wall")
    lines.append(f"{'op':>10} {'waits':>7} {'wait_ms':>10} "
                 f"{'batches':>8} {'device_ms':>10} {'mean_lat_ms':>12}")
    ops = sorted(set(a["queue_wait"]) | set(a["device_wall"])
                 | set(a["complete"]))
    for op in ops:
        w = a["queue_wait"].get(op, {"total_s": 0.0, "n": 0})
        d = a["device_wall"].get(op, {"total_s": 0.0, "batches": 0})
        c = a["complete"].get(op, {"n": 0, "latency_total_s": 0.0})
        mean_lat = 1e3 * c["latency_total_s"] / c["n"] if c["n"] else 0.0
        lines.append(f"{op:>10} {w['n']:>7} {_fmt_ms(w['total_s'])} "
                     f"{d['batches']:>8} {_fmt_ms(d['total_s'])} "
                     f"{mean_lat:>12.2f}")
    return "\n".join(lines)
