"""Bounded streaming accumulators for long-lived serving processes.

`ServeMetrics` used to keep EVERY request latency and queue-depth sample
in a plain list (`latencies.extend` per batch) — at production request
counts a week-old server leaks without bound. :class:`Reservoir` is the
replacement: Vitter's Algorithm R keeps a fixed-size uniform sample for
quantiles while count / sum / min / max stay EXACT (they are O(1)
scalars, no reason to approximate them). The sampler is seeded
deterministically so metric summaries are reproducible run-to-run —
telemetry that jitters between identical runs reads as a regression.

p50/p99 from a 4096-sample uniform reservoir sit well within a few
percent of the exact quantiles for the unimodal-ish latency
distributions serving produces (pinned by tests/test_obs.py against
exact numpy percentiles on 50k lognormal samples).
"""

from __future__ import annotations

import random
from typing import List

__all__ = ["Reservoir"]

DEFAULT_CAPACITY = 4096


class Reservoir:
    """Fixed-memory stream summary: exact moments, sampled quantiles.

    capacity: max retained samples (memory ceiling). Quantiles are
        computed over this uniform sample; count/total/min/max are
        exact regardless of how many values streamed through.
    seed: RNG seed for Algorithm R's replacement draws. Fixed by
        default so two identical runs summarize identically.
    """

    __slots__ = ("capacity", "count", "total", "min", "max",
                 "_sample", "_rng")

    def __init__(self, capacity: int = DEFAULT_CAPACITY, seed: int = 0):
        if capacity <= 0:                # not assert: gone under python -O
            raise ValueError(f"capacity must be > 0, got {capacity}")
        self.capacity = capacity
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._sample: List[float] = []
        self._rng = random.Random(seed)

    def add(self, x: float) -> None:
        x = float(x)
        self.count += 1
        self.total += x
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x
        if len(self._sample) < self.capacity:
            self._sample.append(x)
        else:
            # Algorithm R: keep each of the n seen values with p = cap/n
            j = self._rng.randrange(self.count)
            if j < self.capacity:
                self._sample[j] = x

    def extend(self, xs) -> None:
        for x in xs:
            self.add(x)

    def __len__(self) -> int:
        return self.count

    def __bool__(self) -> bool:
        return self.count > 0

    @property
    def sample_size(self) -> int:
        """Retained samples (≤ capacity) — the actual memory footprint."""
        return len(self._sample)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """q-th percentile (0..100) of the retained sample, linear
        interpolation between order statistics (numpy's default)."""
        if not self._sample:
            return 0.0
        xs = sorted(self._sample)
        if len(xs) == 1:
            return xs[0]
        pos = (q / 100.0) * (len(xs) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(xs) - 1)
        frac = pos - lo
        return xs[lo] * (1.0 - frac) + xs[hi] * frac

    def summary(self) -> dict:
        return {
            "count": self.count,
            "mean": self.mean,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "p50": self.percentile(50),
            "p99": self.percentile(99),
        }
