"""Fig. 3 stage attribution: where does an HE op's wall time go?

The paper's Fig. 3 buckets HE Mul wall time into CRT, NTT, modmul, and
iCRT — the measurement every optimization in §IV follows from. Under
jit those stages fuse into one XLA computation and no host-side clock
can see them, so :class:`StageTimer` only runs on the engine's
`--profile-stages` path, where steps execute eagerly and each stage is
fenced with `jax.block_until_ready` before the clock reads. Stage math
is unchanged either way — profiling is bitwise-identical to serving,
just slower (the fence defeats async dispatch on purpose).

Taxonomy mapping (the Fig. 3 attribution contract, see
docs/OBSERVABILITY.md):

  crt     — limbs → RNS residues (`_crt_b`)
  ntt     — forward NTT *and* inverse NTT (`_ntt_b`, `_intt_b`; the
            paper plots them as one transform bucket)
  modmul  — every eval-domain pointwise product: region-1 Montgomery
            muls and region-2 Shoup key products
  icrt    — RNS residues → limbs (`_icrt_b`)

Un-bucketed remainder (BigInt adds/shifts, automorphism permutes,
placement) is the gap between the stage sum and the op's device wall —
the acceptance gate requires the four buckets to cover ≥90% of mul.

`region("region1"/"region2")` additionally attributes Fig. 2's two
regions (ciphertext product vs key switch) per op.
"""

from __future__ import annotations

import time
from collections import defaultdict
from contextlib import contextmanager
from typing import Callable, Dict, Optional

__all__ = ["STAGES", "StageTimer"]

STAGES = ("crt", "ntt", "modmul", "icrt")


class StageTimer:
    """Accumulate per-op per-stage wall seconds with device fencing.

    tracer: optional :class:`repro.obs.trace.Tracer` — each timed call
        also lands as a cat="stage" span on the "stage" lane.
    clock: injectable for tests (defaults to perf_counter; stage spans
        and the tracer should share one clock so the trace lines up).
    """

    def __init__(self, tracer=None,
                 clock: Optional[Callable[[], float]] = None):
        self.tracer = tracer
        self.clock = clock if clock is not None else time.perf_counter
        self._stage_s: Dict[str, Dict[str, float]] = defaultdict(
            lambda: {s: 0.0 for s in STAGES})
        self._calls: Dict[str, Dict[str, int]] = defaultdict(
            lambda: {s: 0 for s in STAGES})
        self._region_s: Dict[str, Dict[str, float]] = defaultdict(
            lambda: defaultdict(float))
        self._op: str = "?"
        self._paused = 0

    # ---- scoping ----------------------------------------------------------

    @contextmanager
    def op(self, label: str):
        """Attribute nested timed() calls to this op kind ("mul", …)."""
        prev, self._op = self._op, label
        try:
            yield
        finally:
            self._op = prev

    @contextmanager
    def pause(self):
        """Suspend recording (warm-up/compile runs must not pollute the
        steady-state attribution — `OpEngine.warm_batch` wraps its
        throwaway run in this)."""
        self._paused += 1
        try:
            yield
        finally:
            self._paused -= 1

    # ---- recording --------------------------------------------------------

    def timed(self, stage: str, thunk: Callable):
        """Run thunk, fence its outputs on-device, book the elapsed wall
        under (current op, stage). Returns the thunk's result."""
        if self._paused:
            return thunk()
        if stage not in self._stage_s[self._op]:   # gone under python -O
            raise ValueError(f"unknown stage {stage!r}; one of {STAGES}")
        # deferred so importing repro.obs (e.g. from the jax-free
        # frontend metrics path) never pulls in jax; cached after once
        import jax
        t0 = self.clock()
        out = thunk()
        jax.block_until_ready(out)
        dt = self.clock() - t0
        self._stage_s[self._op][stage] += dt
        self._calls[self._op][stage] += 1
        if self.tracer is not None:
            self.tracer.event(stage, cat="stage", lane="stage", ts=t0,
                              dur=dt, args={"op": self._op})
        return out

    @contextmanager
    def region(self, name: str):
        """Attribute a Fig. 2 region ("region1" ciphertext product /
        "region2" key switch) for the current op. Region walls are
        host-elapsed: the stages inside are fenced, so only trailing
        un-bucketed work (BigInt shifts) dispatches past the exit."""
        if self._paused:
            yield
            return
        t0 = self.clock()
        try:
            yield
        finally:
            dt = self.clock() - t0
            self._region_s[self._op][name] += dt
            if self.tracer is not None:
                self.tracer.event(name, cat="stage", lane="stage", ts=t0,
                                  dur=dt, args={"op": self._op})

    # ---- export -----------------------------------------------------------

    def stage_total(self, op: str) -> float:
        """Sum of the four Fig. 3 buckets for one op kind — the
        numerator of the ≥90%-of-device-wall coverage gate."""
        return sum(self._stage_s[op].values()) if op in self._stage_s \
            else 0.0

    def summary(self) -> dict:
        return {
            "stages": {op: {s: v[s] for s in STAGES}
                       for op, v in sorted(self._stage_s.items())},
            "calls": {op: {s: v[s] for s in STAGES}
                      for op, v in sorted(self._calls.items())},
            "regions": {op: dict(v)
                        for op, v in sorted(self._region_s.items())},
        }

    def reset(self) -> None:
        self._stage_s.clear()
        self._calls.clear()
        self._region_s.clear()
