"""Deterministic synthetic LM data: counter-based, restart-reproducible.

Each global step's batch is a pure function of (seed, step) — no stateful
iterators — so a restarted job regenerates byte-identical batches (the
fault-tolerance tests rely on this; real deployments swap in a tokenized
corpus reader with the same interface).

The stream is a mixture of structured patterns (arithmetic mod-V walks and
repeats) so that a model can actually reduce loss on it, plus next-token
labels (shift folded in here, not in the model).
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


class SyntheticLM:
    """Counter-based synthetic batches for any assigned architecture."""

    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int,
                 seed: int = 0, enc_len: Optional[int] = None):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.enc_len = enc_len or 2 * seq_len if cfg.enc_dec else 0

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((self.seed, step))
        B, L, V = self.batch, self.seq_len, cfg.vocab_size
        start = rng.integers(0, V, size=(B, 1))
        stride = rng.integers(1, 7, size=(B, 1))
        seq = (start + stride * np.arange(L + 1)[None, :]) % V
        noise_mask = rng.random((B, L + 1)) < 0.05
        noise = rng.integers(0, V, size=(B, L + 1))
        seq = np.where(noise_mask, noise, seq).astype(np.int32)
        out = {
            "tokens": jnp.asarray(seq[:, :-1]),
            "labels": jnp.asarray(seq[:, 1:]),
        }
        if cfg.enc_dec:
            out["frames"] = jnp.asarray(
                rng.normal(size=(B, self.enc_len, cfg.d_model))
                .astype(np.float32))
        if cfg.frontend == "vision":
            out["patch_embeds"] = jnp.asarray(
                rng.normal(size=(B, cfg.n_frontend_tokens, cfg.d_model))
                .astype(np.float32))
        return out

    def shard_slice(self, batch: Dict[str, jnp.ndarray], proc: int,
                    n_procs: int) -> Dict[str, jnp.ndarray]:
        """Host-side per-process slicing for multi-process launches."""
        per = self.batch // n_procs
        return {k: v[proc * per:(proc + 1) * per] for k, v in batch.items()}


def make_batch_specs(cfg: ModelConfig, batch: int, seq_len: int,
                     enc_len: Optional[int] = None):
    """ShapeDtypeStruct stand-ins for a training batch (dry-run input)."""
    specs = {
        "tokens": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
    }
    if cfg.enc_dec:
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch, enc_len or seq_len, cfg.d_model), jnp.float32)
    if cfg.frontend == "vision":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    return specs
