"""Step-time SLA monitoring and heartbeats (straggler mitigation layer).

At 1000+ nodes the failure you see most is not a crash but a slow pod:
one host's step time degrades (thermals, ECC retries, a flaky ICI link)
and the synchronous collective drags everyone. The monitor keeps an EMA of
step wall-time and flags breaches of ``slack × EMA``; the launcher's policy
(launch/train.py) is then: log → alert → checkpoint-and-exclude. On real
fleets the exclusion triggers a re-slice onto hot spares; in this repo the
re-slice is exercised by the elastic-restart test (different mesh on
restore).

Heartbeat files let an external supervisor detect a hung process (no write
within `timeout`) and kill/restart it — the standard watchdog contract.
"""

from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, Optional


class StepMonitor:
    """EMA step-time SLA with breach-streak re-anchoring.

    The EMA deliberately freezes during a breach (a straggler must not
    drag the baseline up, or the alert stops firing exactly when the
    degradation persists). But a PERMANENT degradation — the pod now
    just runs at 2.5× — would then breach forever, burying real alerts
    in noise. After `reanchor_after` CONSECUTIVE breaches the monitor
    concedes the new normal and re-anchors the baseline to the streak's
    minimum step time, capped at `reanchor_cap × EMA` so one re-anchor
    can never absorb an unbounded regression in a single jump (a 100×
    degradation re-baselines in capped stages, each logged). Re-anchors
    are recorded in `reanchors` — the degrade event the launcher's
    policy escalates on even once the alerts quiesce.
    """

    def __init__(self, ema_alpha: float = 0.1, slack: float = 2.0,
                 warmup_steps: int = 3, reanchor_after: int = 8,
                 reanchor_cap: float = 4.0):
        self.alpha = ema_alpha
        self.slack = slack
        self.warmup = warmup_steps
        self.reanchor_after = reanchor_after
        self.reanchor_cap = reanchor_cap
        self.ema: Optional[float] = None
        self.count = 0
        self.breaches = []
        self.reanchors = []          # (step, old_ema, new_ema)
        self._streak = 0
        self._streak_min = float("inf")
        # per-publisher child monitors (multi-host: one per worker id) —
        # see `record(worker=...)`
        self._per: Dict[object, "StepMonitor"] = {}

    def for_worker(self, worker) -> "StepMonitor":
        """The child monitor for one publisher (same knobs), created on
        first use. A single StepMonitor fed by N workers would mix their
        step-time distributions into one EMA — worker 0's fast steps
        would make worker 1's normal steps read as breaches, and one
        straggling worker would drag every baseline. Namespacing by
        worker id keeps each publisher's SLA independent (the same
        collision the registry's `merge_snapshots` solves for labels)."""
        if worker not in self._per:
            self._per[worker] = StepMonitor(
                ema_alpha=self.alpha, slack=self.slack,
                warmup_steps=self.warmup,
                reanchor_after=self.reanchor_after,
                reanchor_cap=self.reanchor_cap)
        return self._per[worker]

    def record(self, step: int, seconds: float, worker=None) -> bool:
        """Returns True if this step breached the SLA (straggler signal).
        With `worker`, the sample routes to that publisher's child
        monitor instead of the shared baseline."""
        if worker is not None:
            return self.for_worker(worker).record(step, seconds)
        self.count += 1
        if self.count <= self.warmup:
            # min over warmup: the first step carries compilation time and
            # must not poison the baseline.
            self.ema = seconds if self.ema is None else min(self.ema,
                                                            seconds)
            return False
        breach = seconds > self.slack * self.ema
        if breach:
            self.breaches.append((step, seconds, self.ema))
            self._streak += 1
            self._streak_min = min(self._streak_min, seconds)
            if self._streak >= self.reanchor_after:
                # concede the new normal: anchor to the best the streak
                # ever did (not its mean — a recovering pod should not
                # inherit its worst steps), capped so one jump is
                # bounded
                new = min(self._streak_min, self.reanchor_cap * self.ema)
                self.reanchors.append((step, self.ema, new))
                self.ema = new
                self._streak = 0
                self._streak_min = float("inf")
        else:
            self.ema = (1 - self.alpha) * self.ema + self.alpha * seconds
            self._streak = 0
            self._streak_min = float("inf")
        return breach


class Heartbeat:
    """Watchdog file with an optional live-telemetry payload.

    `metrics` duck-types `repro.obs.MetricsRegistry` (anything with a
    `snapshot() -> dict`): each beat embeds the current snapshot under
    a "metrics" key, so the supervisor reading the heartbeat for
    liveness gets the serving telemetry plane for free — the health
    channel the multi-host tier consumes. `metrics` may instead be a
    dict of {publisher_id: registry-or-snapshot}: multiple publishers'
    snapshots are then merged with their label spaces namespaced by
    publisher id (`repro.obs.registry.merge_snapshots`), so two workers
    both counting "worker.batches" never collide in one heartbeat.

    `clock` is the timestamp source for the "time" field AND the
    interval gate (default wall `time.time`). The frontend's in-process
    fault tests inject their fake clock here so `is_alive(..., now=...)`
    compares on one timeline; subprocess workers keep wall time, which
    matches the frontend's wall-clock death detection.
    """

    def __init__(self, path: str, interval: float = 10.0, metrics=None,
                 clock: Callable[[], float] = time.time):
        self.path = path
        self.interval = interval
        self.metrics = metrics
        self._clock = clock
        self._last: Optional[float] = None

    def _metrics_doc(self) -> dict:
        m = self.metrics
        if isinstance(m, dict):
            from repro.obs.registry import merge_snapshots
            return merge_snapshots({
                str(k): (v.snapshot() if hasattr(v, "snapshot")
                         else dict(v))
                for k, v in m.items()})
        return m.snapshot()

    def beat(self, step: int, payload: Optional[dict] = None) -> None:
        now = self._clock()
        if self._last is not None and now - self._last < self.interval:
            return
        self._last = now
        doc = {"step": step, "time": now, **(payload or {})}
        if self.metrics is not None:
            doc["metrics"] = self._metrics_doc()
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f)
        os.replace(tmp, self.path)

    @staticmethod
    def is_alive(path: str, timeout: float,
                 now: Optional[float] = None) -> bool:
        """Whether the file was beaten within `timeout` of `now`
        (default wall time; pass a fake-clock reading when the beats
        were stamped by an injected clock)."""
        try:
            with open(path) as f:
                data = json.load(f)
            t = time.time() if now is None else now
            return t - data["time"] < timeout
        except (OSError, ValueError, KeyError):
            return False
