"""Step-time SLA monitoring and heartbeats (straggler mitigation layer).

At 1000+ nodes the failure you see most is not a crash but a slow pod:
one host's step time degrades (thermals, ECC retries, a flaky ICI link)
and the synchronous collective drags everyone. The monitor keeps an EMA of
step wall-time and flags breaches of ``slack × EMA``; the launcher's policy
(launch/train.py) is then: log → alert → checkpoint-and-exclude. On real
fleets the exclusion triggers a re-slice onto hot spares; in this repo the
re-slice is exercised by the elastic-restart test (different mesh on
restore).

Heartbeat files let an external supervisor detect a hung process (no write
within `timeout`) and kill/restart it — the standard watchdog contract.
"""

from __future__ import annotations

import json
import os
import time
from typing import Optional


class StepMonitor:
    def __init__(self, ema_alpha: float = 0.1, slack: float = 2.0,
                 warmup_steps: int = 3):
        self.alpha = ema_alpha
        self.slack = slack
        self.warmup = warmup_steps
        self.ema: Optional[float] = None
        self.count = 0
        self.breaches = []

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step breached the SLA (straggler signal)."""
        self.count += 1
        if self.count <= self.warmup:
            # min over warmup: the first step carries compilation time and
            # must not poison the baseline.
            self.ema = seconds if self.ema is None else min(self.ema,
                                                            seconds)
            return False
        breach = seconds > self.slack * self.ema
        if breach:
            self.breaches.append((step, seconds, self.ema))
        else:
            self.ema = (1 - self.alpha) * self.ema + self.alpha * seconds
        return breach


class Heartbeat:
    def __init__(self, path: str, interval: float = 10.0):
        self.path = path
        self.interval = interval
        self._last = 0.0

    def beat(self, step: int, payload: Optional[dict] = None) -> None:
        now = time.time()
        if now - self._last < self.interval:
            return
        self._last = now
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": now, **(payload or {})}, f)
        os.replace(tmp, self.path)

    @staticmethod
    def is_alive(path: str, timeout: float) -> bool:
        try:
            with open(path) as f:
                data = json.load(f)
            return time.time() - data["time"] < timeout
        except (OSError, ValueError, KeyError):
            return False
