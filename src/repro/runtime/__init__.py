"""Runtime: heartbeats, failure injection, straggler monitoring, restarts."""

from repro.runtime.monitor import StepMonitor, Heartbeat
from repro.runtime.failures import FailureInjector, SimulatedFailure

__all__ = ["StepMonitor", "Heartbeat", "FailureInjector", "SimulatedFailure"]
