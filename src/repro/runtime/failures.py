"""Deterministic failure injection for fault-tolerance tests.

Simulates the two pod-scale failure classes the launcher must survive:
  - hard failure (process dies mid-step → restart from latest checkpoint),
  - straggler (a step takes k× longer → SLA breach surfaced by StepMonitor).
"""

from __future__ import annotations

import time
from typing import Iterable, Set


class SimulatedFailure(RuntimeError):
    pass


class FailureInjector:
    def __init__(self, fail_at_steps: Iterable[int] = (),
                 straggle_at_steps: Iterable[int] = (),
                 straggle_seconds: float = 0.5):
        self.fail_at: Set[int] = set(fail_at_steps)
        self.straggle_at: Set[int] = set(straggle_at_steps)
        self.straggle_seconds = straggle_seconds
        self.fired: Set[int] = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(f"injected node failure at step {step}")
        if step in self.straggle_at and step not in self.fired:
            self.fired.add(step)
            time.sleep(self.straggle_seconds)
