"""Deterministic failure injection for fault-tolerance tests.

Simulates the pod-scale failure classes the launcher and the multi-host
serving tier must survive:
  - hard failure (process dies mid-step → restart from latest checkpoint),
  - straggler (a step takes k× longer → SLA breach surfaced by StepMonitor),
  - worker death (a serving worker vanishes after its Nth batch → the
    frontend requeues its in-flight work and re-routes; see
    `repro.hserve.frontend.HEFrontend(injector=...)`).
"""

from __future__ import annotations

import time
from typing import Dict, Iterable, Mapping, Set


class SimulatedFailure(RuntimeError):
    pass


class FailureInjector:
    def __init__(self, fail_at_steps: Iterable[int] = (),
                 straggle_at_steps: Iterable[int] = (),
                 straggle_seconds: float = 0.5,
                 kill_worker_at: Mapping[int, int] | None = None):
        self.fail_at: Set[int] = set(fail_at_steps)
        self.straggle_at: Set[int] = set(straggle_at_steps)
        self.straggle_seconds = straggle_seconds
        self.fired: Set[int] = set()
        # worker-kill mode: {worker id: kill after this many dispatched
        # batches}. Deterministic by construction — the frontend asks
        # after every dispatch, and each worker dies at most once.
        self.kill_worker_at: Dict[int, int] = dict(kill_worker_at or {})
        self.killed_workers: Set[int] = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(f"injected node failure at step {step}")
        if step in self.straggle_at and step not in self.fired:
            self.fired.add(step)
            time.sleep(self.straggle_seconds)

    def maybe_kill_worker(self, wid: int, n_batches: int) -> bool:
        """Should worker `wid` die now, having dispatched `n_batches`
        lifetime batches? Fires at most once per worker. The caller
        (the frontend, post-dispatch) actually kills the transport, so
        the batch in flight is lost mid-serve — the requeue path."""
        at = self.kill_worker_at.get(wid)
        if at is not None and n_batches >= at \
                and wid not in self.killed_workers:
            self.killed_workers.add(wid)
            return True
        return False
