"""Pallas TPU kernels for the HE Mul hot spots the paper optimizes.

Four kernels, mirroring the paper's §IV decomposition (CRT / NTT / iNTT /
iCRT ≥ 95.8 % of HE Mul) plus the pointwise modmul stage:

  ntt/     VMEM-resident all-stage negacyclic (i)NTT — the TPU limit of the
           paper's high-radix argument (HBM round trips: log₂N → 1).
  crt/     blocked RNS conversion with 3-word ADC accumulation (GPU-C).
  icrt/    loop-reordered Algo-6 matmul with in-kernel limb assembly.
  modmul/  pointwise Montgomery products (unknown×unknown residues).

All arithmetic is β = 2^32 synthesized from 16-bit partial products
(TPU VPUs have no widening multiply / carry flags — see DESIGN.md §2).
Each kernel ships ops.py (jit wrapper; auto-interpret off-TPU) and ref.py
(pure-jnp oracle); tests sweep shapes and assert exact equality.
"""
