"""Shared kernel plumbing: interpret-mode detection and tiling helpers."""

from __future__ import annotations

import jax

__all__ = ["use_interpret", "pick_block"]


def use_interpret() -> bool:
    """Pallas kernels execute in interpret mode off-TPU (this container is
    CPU-only; TPU v5e is the compile target, not the runtime)."""
    return jax.default_backend() != "tpu"


def pick_block(n: int, preferred: int) -> int:
    """Largest divisor of n that is ≤ preferred (block shapes must tile)."""
    b = min(n, preferred)
    while n % b:
        b -= 1
    return b
