"""Pure-jnp oracle for the Pallas NTT/iNTT kernels.

Delegates to the (independently python-int-validated) core transforms.
"""

from __future__ import annotations

from repro.core.ntt import intt as _intt
from repro.core.ntt import ntt as _ntt

__all__ = ["ntt_ref", "intt_ref"]


def ntt_ref(x, psi_rev, psi_rev_shoup, primes, *, modified: bool = False):
    return _ntt(x, psi_rev, psi_rev_shoup, primes, modified=modified)


def intt_ref(x, ipsi_rev, ipsi_rev_shoup, n_inv, n_inv_shoup, primes, *,
             modified: bool = False):
    return _intt(x, ipsi_rev, ipsi_rev_shoup, n_inv, n_inv_shoup, primes,
                 modified=modified)
