"""VMEM-resident negacyclic NTT / iNTT Pallas kernels (β = 2^32).

TPU adaptation of the paper's high-radix NTT (§V-C, Table IX): on a GPU the
paper raises the radix to cut HBM round trips of the (np, N) working set
from log₂N to log_kN. TPU VMEM (~16 MiB/core) holds an entire N-point row
(N = 2^16 → 256 KiB of u32), so the kernel streams the matrix ONCE, runs
ALL log₂N butterfly stages on-chip, and writes ONCE — radix-N in the
paper's terms, the logical limit of its argument.

Grid: one step per block of `rows` primes (the paper's np-degree
parallelism maps to the grid/sublane dimension; butterflies ride the
128-lane axis). Twiddles (values + Shoup companions) ride along per row.

All modmuls are Shoup (paper Algo 2) built on 16-bit-split mulhi
(DESIGN.md §2 — no widening multiply on TPU VPUs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.wordops import (
    modadd, modsub, shoup_modmul, shoup_modmul_modified,
)
from repro.kernels.common import pick_block, use_interpret


def _ntt_kernel(x_ref, psi_ref, psi_sh_ref, p_ref, o_ref, *, modified):
    rows, N = x_ref.shape
    mm = shoup_modmul_modified if modified else shoup_modmul
    x = x_ref[...]
    psi = psi_ref[...]
    psi_sh = psi_sh_ref[...]
    p = p_ref[...][:, :, None]          # (rows, 1, 1)
    t, m = N, 1
    while m < N:                         # log₂N stages, all in VMEM
        t //= 2
        xr = x.reshape(rows, m, 2, t)
        u = xr[:, :, 0, :]
        v = xr[:, :, 1, :]
        s = psi[:, m: 2 * m, None]
        s_sh = psi_sh[:, m: 2 * m, None]
        vv = mm(v, s, s_sh, p)
        x = jnp.stack([modadd(u, vv, p), modsub(u, vv, p)],
                      axis=2).reshape(rows, N)
        m *= 2
    o_ref[...] = x


def _intt_kernel(x_ref, ipsi_ref, ipsi_sh_ref, ninv_ref, ninv_sh_ref,
                 p_ref, o_ref, *, modified):
    rows, N = x_ref.shape
    mm = shoup_modmul_modified if modified else shoup_modmul
    x = x_ref[...]
    ipsi = ipsi_ref[...]
    ipsi_sh = ipsi_sh_ref[...]
    p3 = p_ref[...][:, :, None]
    t, m = 1, N
    while m > 1:                         # Gentleman-Sande stages
        h = m // 2
        xr = x.reshape(rows, h, 2, t)
        u = xr[:, :, 0, :]
        v = xr[:, :, 1, :]
        s = ipsi[:, h: 2 * h, None]
        s_sh = ipsi_sh[:, h: 2 * h, None]
        lo = modadd(u, v, p3)
        hi = mm(modsub(u, v, p3), s, s_sh, p3)
        x = jnp.stack([lo, hi], axis=2).reshape(rows, N)
        t *= 2
        m = h
    # final elementwise ·N⁻¹ (paper §IV)
    o_ref[...] = mm(x, ninv_ref[...], ninv_sh_ref[...], p_ref[...])


def _rows_for(npn: int, N: int) -> int:
    # VMEM budget ≈ 6 live row-sized arrays (x, ψ, ψ_shoup, out, temps).
    budget_words = (4 << 20) // 4
    return pick_block(npn, max(1, budget_words // (6 * N)))


@functools.partial(jax.jit, static_argnames=("modified", "interpret"))
def ntt_pallas(x, psi_rev, psi_rev_shoup, primes, *, modified=False,
               interpret=None):
    """(np, N) natural-order residues -> bit-reversed eval domain."""
    npn, N = x.shape
    rows = _rows_for(npn, N)
    interp = use_interpret() if interpret is None else interpret
    row_spec = pl.BlockSpec((rows, N), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_ntt_kernel, modified=modified),
        grid=(npn // rows,),
        in_specs=[row_spec, row_spec, row_spec,
                  pl.BlockSpec((rows, 1), lambda i: (i, 0))],
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((npn, N), x.dtype),
        interpret=interp,
    )(x, psi_rev, psi_rev_shoup, primes[:, None])


@functools.partial(jax.jit, static_argnames=("modified", "interpret"))
def intt_pallas(x, ipsi_rev, ipsi_rev_shoup, n_inv, n_inv_shoup, primes, *,
                modified=False, interpret=None):
    """(np, N) bit-reversed eval domain -> natural-order residues."""
    npn, N = x.shape
    rows = _rows_for(npn, N)
    interp = use_interpret() if interpret is None else interpret
    row_spec = pl.BlockSpec((rows, N), lambda i: (i, 0))
    col_spec = pl.BlockSpec((rows, 1), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_intt_kernel, modified=modified),
        grid=(npn // rows,),
        in_specs=[row_spec, row_spec, row_spec, col_spec, col_spec, col_spec],
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((npn, N), x.dtype),
        interpret=interp,
    )(x, ipsi_rev, ipsi_rev_shoup, n_inv[:, None], n_inv_shoup[:, None],
      primes[:, None])
