"""Jit'd public wrappers for the Pallas NTT/iNTT kernels."""

from __future__ import annotations

from repro.kernels.ntt.ntt import intt_pallas, ntt_pallas

__all__ = ["ntt_op", "intt_op"]


def ntt_op(x, psi_rev, psi_rev_shoup, primes, *, modified: bool = False):
    """Forward negacyclic NTT: (np, N) residues -> bit-reversed eval."""
    return ntt_pallas(x, psi_rev, psi_rev_shoup, primes, modified=modified)


def intt_op(x, ipsi_rev, ipsi_rev_shoup, n_inv, n_inv_shoup, primes, *,
            modified: bool = False):
    """Inverse negacyclic NTT: bit-reversed eval -> (np, N) residues."""
    return intt_pallas(x, ipsi_rev, ipsi_rev_shoup, n_inv, n_inv_shoup,
                       primes, modified=modified)
