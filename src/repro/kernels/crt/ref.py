"""Pure-jnp oracle for the Pallas CRT kernel."""

from __future__ import annotations

from repro.core.crt import crt as _crt

__all__ = ["crt_ref"]


def crt_ref(x, tb, tb_shoup, primes, *, strategy: str = "matmul"):
    return _crt(x, tb, tb_shoup, primes, strategy=strategy)
