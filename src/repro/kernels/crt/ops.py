"""Jit'd public wrapper for the Pallas CRT kernel (β = 2^32 only)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.crt.crt import crt_pallas

__all__ = ["crt_op"]


def crt_op(x, tb, tb_shoup, primes, *, strategy: str = "acc3"):
    """(N, K) limbs -> (np, N) residues. Strategies: acc3 | mod2 | mod4."""
    assert x.dtype == jnp.uint32, "Pallas kernels are β=2^32 (TPU-native)"
    return crt_pallas(x, tb, tb_shoup, primes, strategy=strategy)
