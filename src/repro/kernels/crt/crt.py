"""Blocked CRT Pallas kernel (paper Algo 1, GPU-C accumulation).

out[j, n] = mod(Σ_k in[n, k]·(β^k mod p_j), p_j)

Tiling: grid (np/npb, N/nb); each step loads an input tile (nb, K), the
table tile (npb, K) and produces (npb, nb) residues. Accumulation follows
the paper's winning CRT strategy (Table VIII "GPU-C"): raw 16-bit-split
products into a 3-word accumulator with synthesized ADC, ONE fold at the
end through Shoup multiplies by {1, β, β²} mod p — no per-iteration modulo.
A delayed-modulo variant ("modx", Table VIII Mod-2/Mod-4) is provided for
the benchmark ladder.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.wordops import (
    acc3_add_product, cond_reduce, mul_wide, shoup_modmul,
)
from repro.kernels.common import pick_block, use_interpret


def _crt_kernel_acc3(x_ref, tb_ref, tb_sh_ref, p_ref, o_ref):
    npb, K = tb_ref.shape
    nb = x_ref.shape[0]
    x = x_ref[...]                      # (nb, K)
    tb = tb_ref[...]                    # (npb, K)
    tb_sh = tb_sh_ref[...]
    p = p_ref[...]                      # (npb, 1)
    zeros = jnp.zeros((npb, nb), x.dtype)
    a2, a1, a0 = zeros, zeros, zeros
    for k in range(K):                  # static unroll; K ≤ ~76
        a2, a1, a0 = acc3_add_product(
            a2, a1, a0,
            jnp.broadcast_to(x[None, :, k], (npb, nb)),
            jnp.broadcast_to(tb[:, k, None], (npb, nb)))
    # fold 3-word accumulator: Shoup by β^k mod p (k = 0,1,2); tb[:,0] = 1.
    r0 = shoup_modmul(a0, tb[:, 0, None], tb_sh[:, 0, None], p)
    r1 = shoup_modmul(a1, tb[:, 1, None], tb_sh[:, 1, None], p)
    r2 = shoup_modmul(a2, tb[:, 2, None], tb_sh[:, 2, None], p)
    o_ref[...] = cond_reduce(r0 + r1 + r2, p, 4)


def _crt_kernel_modx(x_ref, tb_ref, tb_sh_ref, p_ref, o_ref, *, every):
    """Delayed-modulo ladder (Table VIII Mod-x): Shoup-fold every x terms."""
    npb, K = tb_ref.shape
    nb = x_ref.shape[0]
    x = x_ref[...]
    tb = tb_ref[...]
    tb_sh = tb_sh_ref[...]
    p = p_ref[...]
    acc_hi = jnp.zeros((npb, nb), x.dtype)
    acc_lo = jnp.zeros((npb, nb), x.dtype)
    out = jnp.zeros((npb, nb), x.dtype)

    def fold(out, acc_hi, acc_lo):
        r0 = shoup_modmul(acc_lo, tb[:, 0, None], tb_sh[:, 0, None], p)
        r1 = shoup_modmul(acc_hi, tb[:, 1, None], tb_sh[:, 1, None], p)
        return cond_reduce(out + r0 + r1, p, 4)

    for k in range(K):
        hi, lo = mul_wide(jnp.broadcast_to(x[None, :, k], (npb, nb)),
                          jnp.broadcast_to(tb[:, k, None], (npb, nb)))
        new_lo = acc_lo + lo
        carry = (new_lo < lo).astype(x.dtype)
        acc_hi = acc_hi + hi + carry    # safe: ≤ `every` products, hi < β-1
        acc_lo = new_lo
        if (k + 1) % every == 0 or k == K - 1:
            out = fold(out, acc_hi, acc_lo)
            acc_hi = jnp.zeros_like(acc_hi)
            acc_lo = jnp.zeros_like(acc_lo)
    o_ref[...] = out


@functools.partial(jax.jit,
                   static_argnames=("strategy", "interpret"))
def crt_pallas(x, tb, tb_shoup, primes, *, strategy: str = "acc3",
               interpret=None):
    """(N, K) limbs -> (np, N) residues."""
    N, K = x.shape
    npn = tb.shape[0]
    nb = pick_block(N, 256)
    npb = pick_block(npn, 8)
    interp = use_interpret() if interpret is None else interpret
    if strategy == "acc3":
        kern = _crt_kernel_acc3
    elif strategy.startswith("mod"):
        kern = functools.partial(_crt_kernel_modx, every=int(strategy[3:]))
    else:
        raise ValueError(f"unknown kernel CRT strategy {strategy!r}")
    return pl.pallas_call(
        kern,
        grid=(npn // npb, N // nb),
        in_specs=[
            pl.BlockSpec((nb, K), lambda j, i: (i, 0)),
            pl.BlockSpec((npb, K), lambda j, i: (j, 0)),
            pl.BlockSpec((npb, K), lambda j, i: (j, 0)),
            pl.BlockSpec((npb, 1), lambda j, i: (j, 0)),
        ],
        out_specs=pl.BlockSpec((npb, nb), lambda j, i: (j, i)),
        out_shape=jax.ShapeDtypeStruct((npn, N), x.dtype),
        interpret=interp,
    )(x, tb, tb_shoup, primes[:, None])
