"""Pointwise Montgomery modmul Pallas kernel (β = 2^32).

The eval-domain ciphertext⊙ciphertext products (paper Fig. 2 white circles)
are unknown×unknown, so Shoup does not apply; Montgomery REDC (2 REDCs,
domain-free) replaces hardware division. Trivially parallel: grid over
(np, N) tiles.
"""

from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl

from repro.core.wordops import mont_modmul
from repro.kernels.common import pick_block, use_interpret


def _modmul_kernel(a_ref, b_ref, p_ref, pp_ref, r2_ref, o_ref):
    o_ref[...] = mont_modmul(a_ref[...], b_ref[...], p_ref[...],
                             pp_ref[...], r2_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def pointwise_mont_pallas(a, b, primes, pprime, r2, *, interpret=None):
    npn, N = a.shape
    nb = pick_block(N, 2048)
    npb = pick_block(npn, 8)
    interp = use_interpret() if interpret is None else interpret
    tile = pl.BlockSpec((npb, nb), lambda j, i: (j, i))
    col = pl.BlockSpec((npb, 1), lambda j, i: (j, 0))
    return pl.pallas_call(
        _modmul_kernel,
        grid=(npn // npb, N // nb),
        in_specs=[tile, tile, col, col, col],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((npn, N), a.dtype),
        interpret=interp,
    )(a, b, primes[:, None], pprime[:, None], r2[:, None])
