"""Jit'd public wrapper for the Pallas pointwise-modmul kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.modmul.modmul import pointwise_mont_pallas

__all__ = ["pointwise_mont_op"]


def pointwise_mont_op(a, b, primes, pprime, r2):
    assert a.dtype == jnp.uint32, "Pallas kernels are β=2^32 (TPU-native)"
    return pointwise_mont_pallas(a, b, primes, pprime, r2)
