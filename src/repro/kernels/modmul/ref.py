"""Pure-jnp oracle for the pointwise-modmul kernel."""

from __future__ import annotations

from repro.core.wordops import mont_modmul

__all__ = ["pointwise_mont_ref"]


def pointwise_mont_ref(a, b, primes, pprime, r2):
    return mont_modmul(a, b, primes[:, None], pprime[:, None], r2[:, None])
