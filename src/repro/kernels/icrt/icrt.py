"""Loop-reordered iCRT Pallas kernel (paper Algo 6).

The paper's key algorithmic move (§V-A): iCRT's scalar×BigInt accumulation
becomes an (np × PLimbs) matrix product per coefficient, exposing N·PLimbs
parallelism. The kernel fuses, per N-block:

  (1) the Hadamard step  temp[j,n] = mod(r[j,n]·(P/p_j)⁻¹, p_j)   [Shoup]
  (2) the reordered matmul  Σ_j temp[j,n]·(P/p_j)[limb k]  into 3-word
      accumulators (synthesized ADC)
  (3) limb assembly with carry propagation  -> accum (nb, A)
  (4) the fixed-point quotient  s ≈ Σ_j temp[j,n]·⌊β²/p_j⌋ / β²  — the TPU
      replacement for the f64 quotient (no f64 on TPU; ±1 error is fixed by
      the shared correction ladder in core.crt.finalize_accum).

Outputs: accum limbs (N, A) and the quotient estimate (N, 1). The cheap
O(N·A) tail (−s·P, corrections, center-lift) runs in plain JAX (ops.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.wordops import acc3_add_product, shoup_modmul
from repro.kernels.common import pick_block, use_interpret


def _icrt_kernel(r_ref, invp_ref, invp_sh_ref, pdivp_ref, qfix_ref, p_ref,
                 acc_out_ref, s_out_ref):
    npn, nb = r_ref.shape
    PL = pdivp_ref.shape[1]
    A = acc_out_ref.shape[1]
    dt = r_ref.dtype

    # (1) Hadamard (Shoup)
    temp = shoup_modmul(r_ref[...], invp_ref[...], invp_sh_ref[...],
                        p_ref[...])                       # (np, nb)
    pdivp = pdivp_ref[...]                                # (np, PL)
    qfix = qfix_ref[...]                                  # (np, 2)

    # (2) reordered matmul into 3-word accumulators (nb, PL)
    zeros = jnp.zeros((nb, PL), dt)
    a2, a1, a0 = zeros, zeros, zeros
    # and the fixed-point quotient accumulator (nb,)
    z1 = jnp.zeros((nb,), dt)
    s2, s1, s0 = z1, z1, z1
    for j in range(npn):                    # static unroll over primes
        tj = temp[j]                        # (nb,)
        a2, a1, a0 = acc3_add_product(
            a2, a1, a0, jnp.broadcast_to(tj[:, None], (nb, PL)),
            jnp.broadcast_to(pdivp[j][None, :], (nb, PL)))
        s2, s1, s0 = acc3_add_product(s2, s1, s0, tj,
                                      jnp.broadcast_to(qfix[j, 0], (nb,)))
        hi, lo = _mul_wide_vec(tj, qfix[j, 1])
        # qfix[j,1] is the β¹ word: product lands one word higher
        ns1 = s1 + lo
        c = (ns1 < lo).astype(dt)
        s1 = ns1
        s2 = s2 + hi + c
    # quotient = word 2 of Σ t_j·⌊β²/p_j⌋ (value/β²), error ∈ {0, -1}
    s_out_ref[...] = s2[:, None]

    # (3) limb assembly: Σ_k (a0 + a1β + a2β²)_k β^k with carry chains
    carry = jnp.zeros((nb,), dt)
    for t in range(A):
        w0 = a0[:, t] if t < PL else jnp.zeros((nb,), dt)
        w1 = a1[:, t - 1] if 0 <= t - 1 < PL else jnp.zeros((nb,), dt)
        w2 = a2[:, t - 2] if 0 <= t - 2 < PL else jnp.zeros((nb,), dt)
        v0 = w0 + w1
        c0 = (v0 < w1).astype(dt)
        v1 = v0 + w2
        c1 = (v1 < w2).astype(dt)
        v2 = v1 + carry
        c2 = (v2 < carry).astype(dt)
        acc_out_ref[:, t] = v2
        carry = c0 + c1 + c2            # ≤ 3: absorbed next limb

    # NOTE: carry after the top limb is provably zero (Σ < β^A).


def _mul_wide_vec(a, b):
    from repro.core.wordops import mul_wide
    return mul_wide(a, jnp.broadcast_to(b, a.shape))


@functools.partial(jax.jit, static_argnames=("accum_limbs", "interpret"))
def icrt_accum_pallas(r, inv_P, inv_P_shoup, pdivp, quot_fix, primes, *,
                      accum_limbs: int, interpret=None):
    """(np, N) residues -> (accum (N, A), s_estimate (N,))."""
    npn, N = r.shape
    PL = pdivp.shape[1]
    nb = pick_block(N, 128)
    interp = use_interpret() if interpret is None else interpret
    col = pl.BlockSpec((npn, 1), lambda i: (0, 0))
    acc, s = pl.pallas_call(
        _icrt_kernel,
        grid=(N // nb,),
        in_specs=[
            pl.BlockSpec((npn, nb), lambda i: (0, i)),
            col, col,
            pl.BlockSpec((npn, PL), lambda i: (0, 0)),
            pl.BlockSpec((npn, 2), lambda i: (0, 0)),
            col,
        ],
        out_specs=[
            pl.BlockSpec((nb, accum_limbs), lambda i: (i, 0)),
            pl.BlockSpec((nb, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, accum_limbs), r.dtype),
            jax.ShapeDtypeStruct((N, 1), r.dtype),
        ],
        interpret=interp,
    )(r, inv_P[:, None], inv_P_shoup[:, None], pdivp, quot_fix,
      primes[:, None])
    return acc, s[:, 0]
