"""Jit'd public wrapper for the Pallas iCRT kernel (β = 2^32 only).

Kernel: Hadamard + reordered matmul + limb assembly + fixed-point quotient.
JAX tail: −s·P, ±1 corrections, center-lift (core.crt.finalize_accum).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.context import GlobalTables, IcrtTables
from repro.core.crt import finalize_accum
from repro.kernels.icrt.icrt import icrt_accum_pallas

__all__ = ["icrt_op"]


def icrt_op(r, tabs: IcrtTables, g: GlobalTables, out_limbs: int):
    """(np, N) eval residues -> (N, out_limbs) centered two's complement."""
    assert r.dtype == jnp.uint32, "Pallas kernels are β=2^32 (TPU-native)"
    npn = r.shape[0]
    accum, s = icrt_accum_pallas(
        r, jnp.asarray(tabs.inv_P), jnp.asarray(tabs.inv_P_shoup),
        jnp.asarray(tabs.pdivp), jnp.asarray(tabs.quot_fix),
        jnp.asarray(g.primes[:npn]), accum_limbs=tabs.accum_limbs)
    return finalize_accum(accum, s, jnp.asarray(tabs.P_limbs),
                          jnp.asarray(tabs.P_half_limbs), out_limbs)
