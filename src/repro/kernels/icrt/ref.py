"""Pure-jnp oracle for the Pallas iCRT kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.context import GlobalTables, IcrtTables
from repro.core.crt import icrt as _icrt

__all__ = ["icrt_ref"]


def icrt_ref(r, tabs: IcrtTables, g: GlobalTables, out_limbs: int,
             strategy: str = "matmul"):
    npn = r.shape[0]
    return _icrt(r, tabs, jnp.asarray(g.primes[:npn]),
                 jnp.asarray(tabs.inv_P), jnp.asarray(tabs.inv_P_shoup),
                 jnp.asarray(tabs.pdivp), jnp.asarray(tabs.P_limbs),
                 jnp.asarray(tabs.P_half_limbs),
                 jnp.asarray(g.p_inv_f64[:npn]),
                 out_limbs=out_limbs, strategy=strategy)
