"""Optimizer substrate: AdamW, clipping, schedules, gradient compression."""

from repro.optim.adamw import adamw_init, adamw_update, OptState
from repro.optim.schedule import warmup_cosine
from repro.optim.compress import compress_int8, decompress_int8

__all__ = ["adamw_init", "adamw_update", "OptState", "warmup_cosine",
           "compress_int8", "decompress_int8"]
