"""Gradient compression for the explicit-collective DP path.

int8 block quantization with stochastic rounding: each 256-value block
carries an f32 scale; all-reducing the int8 payload cuts DP gradient
traffic 4× vs f32 (it composes with the shard_map training step in
repro.dist.collectives — compress, psum, decompress).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    n = x.size
    pad = (-n) % BLOCK
    return jnp.pad(x.reshape(-1), (0, pad)), n


def compress_int8(x: jnp.ndarray, key) -> tuple:
    """f32 array -> (int8 payload (N/B, B), f32 scales (N/B,), orig shape)."""
    flat, n = _pad_to_block(x.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = blocks / scale
    noise = jax.random.uniform(key, q.shape, jnp.float32, -0.5, 0.5)
    q8 = jnp.clip(jnp.round(q + noise), -127, 127).astype(jnp.int8)
    return q8, scale[:, 0], (x.shape, n)


def decompress_int8(q8, scale, meta) -> jnp.ndarray:
    shape, n = meta
    flat = (q8.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]
    return flat.reshape(shape)
