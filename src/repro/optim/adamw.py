"""AdamW with decoupled weight decay and global-norm clipping.

Moments are kept in f32 regardless of param dtype (bf16 training safety);
the update is computed in f32 and cast back. State is a plain pytree so the
checkpoint and sharding layers treat it like params.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params, moments_dtype=jnp.float32) -> OptState:
    """moments_dtype=bfloat16 halves optimizer HBM + checkpoint traffic
    (§Perf lever; update math still runs in f32)."""
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, moments_dtype), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))),
        tree, jnp.zeros((), jnp.float32)))


def adamw_update(grads, state: OptState, params, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1, clip_norm=1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        mdt = m.dtype
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m2 / c1
        vhat = v2 / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + \
            weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2.astype(mdt), v2.astype(mdt)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step=step, mu=new_m, nu=new_v), {
        "grad_norm": gnorm, "clip_scale": scale}
