"""Roofline analysis (deliverable g): three terms per (arch × shape) cell.

Reads the dry-run's JSONL records (per-device HLO costs from the compiled
16×16-mesh modules, scan-corrected — see EXPERIMENTS.md §Roofline
methodology) and derives, per cell:

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_operand_bytes_per_device / link_bw

(The assignment's chips-denominator is already folded in: partitioned HLO
shapes are per-device.) Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s
HBM, 50 GB/s/link ICI.

MODEL_FLOPS = 6·N_params_active·D_tokens (train) or 2·N·D (inference),
so the MODEL/HLO ratio exposes remat/emulation/dispatch overheads.
"""

from __future__ import annotations

import json
import sys
from typing import Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
CHIPS = 256

_PARAM_COUNTS = {  # total / active params (analytic, embeddings included)
    "llama3.2-1b": (1.24e9, 1.24e9),
    "h2o-danube-1.8b": (1.83e9, 1.83e9),
    "phi4-mini-3.8b": (3.84e9, 3.84e9),
    "qwen2.5-32b": (32.8e9, 32.8e9),
    "kimi-k2-1t-a32b": (1.04e12, 32.6e9),
    "arctic-480b": (482e9, 26.6e9),
    "recurrentgemma-2b": (2.51e9, 2.51e9),
    "falcon-mamba-7b": (7.27e9, 7.27e9),
    "whisper-base": (7.25e7, 7.25e7),
    "llava-next-mistral-7b": (7.24e9, 7.24e9),
}

_SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 1 * 128,
    "long_500k": 1 * 1,
}


def model_flops(arch: str, shape: str) -> Optional[float]:
    if arch not in _PARAM_COUNTS:
        return None
    total, active = _PARAM_COUNTS[arch]
    toks = _SHAPE_TOKENS[shape]
    if shape == "train_4k":
        return 6.0 * active * toks
    return 2.0 * active * toks


def analyze_record(rec: dict) -> Optional[dict]:
    if not rec.get("ok") or rec.get("skipped"):
        return None
    a = rec.get("analysis", {})
    corr = a.get("corrected") or {}
    flops = corr.get("flops") or a.get("flops")
    bytes_acc = corr.get("bytes_accessed") or a.get("bytes_accessed")
    coll = corr.get("collective_bytes")
    if coll is None:
        coll = a.get("collectives", {}).get("total_bytes")
    if flops is None:
        return None
    t_c = flops / PEAK_FLOPS
    t_m = (bytes_acc or 0) / HBM_BW
    t_l = (coll or 0) / LINK_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_l, "collective"))
    arch, _, shape = rec["cell"].partition("/")
    mf = model_flops(arch, shape)
    mf_dev = mf / CHIPS if mf else None
    return {
        "cell": rec["cell"],
        "mesh": rec.get("mesh"),
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_l,
        "bottleneck": dom[1],
        "step_s_lower_bound": max(t_c, t_m, t_l),
        "roofline_fraction": dom and t_c / max(t_c, t_m, t_l),
        "model_flops_per_dev": mf_dev,
        "model_over_hlo": (mf_dev / flops) if mf_dev else None,
        "hlo_flops_per_dev": flops,
        "hlo_bytes_per_dev": bytes_acc,
        "coll_bytes_per_dev": coll,
    }


def load(path: str = "dryrun_results.jsonl", mesh: str = "pod16x16"):
    rows = {}
    for line in open(path):
        rec = json.loads(line)
        if rec.get("mesh") != mesh:
            continue
        r = analyze_record(rec)
        if r:
            rows[r["cell"]] = r       # last write wins (re-runs)
    return list(rows.values())


def run(full: bool = False, path: str = "dryrun_results.jsonl") -> None:
    from benchmarks.common import row
    try:
        rows = load(path)
    except FileNotFoundError:
        print(f"# roofline: {path} not found — run "
              "`python -m repro.launch.dryrun --he` first", file=sys.stderr)
        return
    for r in sorted(rows, key=lambda r: -r["step_s_lower_bound"]):
        row(f"roofline/{r['cell']}", r["step_s_lower_bound"] * 1e6,
            f"bottleneck={r['bottleneck']} "
            f"compute={r['compute_s']:.3e}s "
            f"memory={r['memory_s']:.3e}s "
            f"collective={r['collective_s']:.3e}s "
            f"model/hlo={r['model_over_hlo'] and round(r['model_over_hlo'], 3)}")


if __name__ == "__main__":
    run()
