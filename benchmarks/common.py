"""Shared benchmark plumbing.

Paper-faithful parameters are (logN, logQ, logp) = (16, 1200, 30) — pass
--full for those. The default is logN=14, logQ=600 so the whole suite runs
in minutes on this CPU container; every table reports its parameter set and
derived columns scale as the paper's §VIII analysis predicts.
"""

from __future__ import annotations

import time
from typing import Callable

import jax

from repro.core.params import HEParams


def bench_params(full: bool = False, beta_bits: int = 32) -> HEParams:
    if full:
        return HEParams(logN=16, logQ=1200, logp=30, log_delta=30,
                        beta_bits=beta_bits)
    return HEParams(logN=14, logQ=600, logp=30, log_delta=30,
                    beta_bits=beta_bits)


def timeit(fn: Callable, *args, reps: int = 3, warmup: int = 1, **kw):
    """Median wall time in seconds; blocks on jax outputs."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2], out


def row(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    return line
