"""Paper Fig. 3: HE Mul execution-time breakdown.

Times each stage of the Fig. 2 pipeline (region 1: 4 CRT, 4 NTT, 3 pointwise,
3 iNTT, 3 iCRT; region 2: 1 CRT+NTT, 2 pointwise, 2 iNTT+iCRT, shifts/adds)
on the real shapes the full HE Mul uses, and reports each function's share.
Paper: CRT+NTT+iNTT+iCRT = 95.8 % of 5,108 ms single-thread.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_params, row, timeit
from repro.core.context import make_context
from repro.core.crt import crt, icrt
from repro.core.ntt import intt, ntt
from repro.core.wordops import mont_modmul
from repro.nt.residue import ints_to_limb_array

import random


def run(full: bool = False) -> None:
    params = bench_params(full)
    logq = params.logQ
    ctx = make_context(params, logq)
    g = ctx.tables
    N, K = ctx.N, ctx.qlimbs
    pr = random.Random(0)
    x = jnp.asarray(ints_to_limb_array(
        [pr.getrandbits(logq) for _ in range(N)], K, params.beta_bits))

    totals = {}
    for region, npn, n_crt, n_ntt, n_pw, n_intt, n_icrt in (
            (1, ctx.np1, 4, 4, 3, 3, 3),
            (2, ctx.np2, 1, 1, 2, 2, 2)):
        tabs = ctx.icrt1 if region == 1 else ctx.icrt2
        crt_args = (jnp.asarray(g.crt_tb[:npn, :K]),
                    jnp.asarray(g.crt_tb_shoup[:npn, :K]),
                    jnp.asarray(g.primes[:npn]))
        t_crt, res = timeit(lambda: crt(x, *crt_args), reps=2)
        ntt_args = (jnp.asarray(g.psi_rev[:npn]),
                    jnp.asarray(g.psi_rev_shoup[:npn]),
                    jnp.asarray(g.primes[:npn]))
        t_ntt, ev = timeit(lambda: ntt(res, *ntt_args), reps=2)
        t_pw, prod = timeit(lambda: mont_modmul(
            ev, ev, jnp.asarray(g.primes[:npn])[:, None],
            jnp.asarray(g.pprime[:npn])[:, None],
            jnp.asarray(g.r2[:npn])[:, None]), reps=2)
        intt_args = (jnp.asarray(g.ipsi_rev[:npn]),
                     jnp.asarray(g.ipsi_rev_shoup[:npn]),
                     jnp.asarray(g.n_inv[:npn]),
                     jnp.asarray(g.n_inv_shoup[:npn]),
                     jnp.asarray(g.primes[:npn]))
        t_intt, back = timeit(lambda: intt(prod, *intt_args), reps=2)
        t_icrt, _ = timeit(lambda: icrt(
            back, tabs, jnp.asarray(g.primes[:npn]),
            jnp.asarray(tabs.inv_P), jnp.asarray(tabs.inv_P_shoup),
            jnp.asarray(tabs.pdivp), jnp.asarray(tabs.P_limbs),
            jnp.asarray(tabs.P_half_limbs),
            jnp.asarray(g.p_inv_f64[:npn]),
            out_limbs=K), reps=2)
        totals.setdefault("CRT", 0.0)
        totals["CRT"] = totals.get("CRT", 0) + n_crt * t_crt
        totals["NTT"] = totals.get("NTT", 0) + n_ntt * t_ntt
        totals["Extra(pointwise)"] = totals.get("Extra(pointwise)", 0) \
            + n_pw * t_pw
        totals["iNTT"] = totals.get("iNTT", 0) + n_intt * t_intt
        totals["iCRT"] = totals.get("iCRT", 0) + n_icrt * t_icrt

    total = sum(totals.values())
    core4 = sum(totals[k] for k in ("CRT", "NTT", "iNTT", "iCRT"))
    for k, v in totals.items():
        row(f"fig3/{k}", v * 1e6, f"{100*v/total:.1f}%")
    row("fig3/core4_share", core4 * 1e6,
        f"{100*core4/total:.1f}% (paper: 95.8%)")


if __name__ == "__main__":
    run()
