"""§Perf hillclimb driver: lower variant configs of the three chosen cells
and record the roofline deltas (hypothesis → change → before/after log is
kept in EXPERIMENTS.md §Perf).

    PYTHONPATH=src python -m benchmarks.hillclimb --cell he
    PYTHONPATH=src python -m benchmarks.hillclimb --cell mamba
    PYTHONPATH=src python -m benchmarks.hillclimb --cell qwen
    (writes hillclimb_results.jsonl)

Cells (chosen per the assignment from the baseline table):
  he    heaan_mul/he_mul_b64      — most representative of the paper's
                                    technique + highest collective/compute.
  mamba falcon-mamba-7b/train_4k  — worst roofline fraction (MODEL/HLO
                                    0.39: emulation + scan waste).
  qwen  qwen2.5-32b/train_4k      — most collective-bound (abs bytes).
"""

from __future__ import annotations

import os

if "--xla" not in str(os.environ.get("XLA_FLAGS", "")):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json

import jax
import jax.numpy as jnp

import repro.core  # noqa: F401


def _emit(path, rec):
    print(f"{rec['variant']:28s} flops={rec['analysis'].get('flops'):.4} "
          f"bytes={rec['analysis'].get('bytes_accessed'):.4} "
          f"coll={rec['analysis']['collectives']['total_bytes']:.4} "
          f"peak={rec['analysis'].get('memory', {}).get('temp_bytes')}",
          flush=True)
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")


def climb_he(out_path):
    from repro.configs.heaan_mul import CONFIG as HEP
    from repro.dist import he_pipeline as hp
    from repro.dist.sharding import he_limb_sharding
    from repro.launch.dryrun import _analyze
    from repro.launch.mesh import make_production_mesh
    import time

    mesh = make_production_mesh()
    st = hp.he_static(HEP, HEP.logQ)
    batch = 64
    variants = [
        ("he-base(matmul,AR)", dict(icrt_strategy="matmul",
                                    reduce_scatter_icrt=False)),
        ("he-rs(matmul,RS-icrt)", dict(icrt_strategy="matmul",
                                       reduce_scatter_icrt=True)),
        ("he-acc3(u32-only,AR)", dict(icrt_strategy="acc3",
                                      reduce_scatter_icrt=False)),
        ("he-rs-acc3(u32-only,RS)", dict(icrt_strategy="acc3",
                                         reduce_scatter_icrt=True)),
    ]
    for name, kw in variants:
        step = hp.make_he_mul_step(st, mesh, **kw)
        t1, t2, ek = hp.he_table_specs(st)
        cts = hp.he_input_specs(st, batch)
        sh = he_limb_sharding(mesh, batch=batch)
        cts = tuple(jax.ShapeDtypeStruct(c.shape, c.dtype, sharding=sh)
                    for c in cts)
        t0 = time.time()
        lowered = jax.jit(step).lower(t1, t2, ek, *cts)
        compiled = lowered.compile()
        rec = {"cell": "heaan_mul/he_mul_b64", "variant": name,
               "analysis": _analyze(lowered, compiled, time.time() - t0)}
        _emit(out_path, rec)


def climb_lm(arch, shape, variants, out_path):
    from repro.launch.dryrun import lower_lm_cell
    from repro.launch.mesh import make_production_mesh
    mesh = make_production_mesh()
    for name, overrides, opt_dtype, mode in variants:
        a = lower_lm_cell(arch, shape, mesh, cost_correct=True,
                          overrides=overrides, opt_dtype=opt_dtype,
                          sharding_mode=mode)
        rec = {"cell": f"{arch}/{shape}", "variant": name, "analysis": a}
        _emit(out_path, rec)


MAMBA_VARIANTS = [
    ("mamba-base(chunk128,full)", None, None, "fsdp"),
    ("mamba-zero1", None, None, "zero1"),
    ("mamba-chunk512", dict(ssm_chunk=512), None, "fsdp"),
    ("mamba-zero1-chunk512", dict(ssm_chunk=512), None, "zero1"),
    ("mamba-zero1-c512-dots", dict(ssm_chunk=512, remat_policy="dots"),
     None, "zero1"),
]

QWEN_VARIANTS = [
    ("qwen-base(fsdp,full-remat)", None, None, "fsdp"),
    ("qwen-zero1", None, None, "zero1"),
    ("qwen-zero1-dots", dict(remat_policy="dots"), None, "zero1"),
    ("qwen-zero1-bf16mom", None, jnp.bfloat16, "zero1"),
    ("qwen-zero1-dots-bf16mom", dict(remat_policy="dots"), jnp.bfloat16,
     "zero1"),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=["he", "mamba", "qwen", "all"],
                    default="all")
    ap.add_argument("--out", default="hillclimb_results.jsonl")
    args = ap.parse_args()
    if args.cell in ("he", "all"):
        climb_he(args.out)
    if args.cell in ("mamba", "all"):
        climb_lm("falcon-mamba-7b", "train_4k", MAMBA_VARIANTS, args.out)
    if args.cell in ("qwen", "all"):
        climb_lm("qwen2.5-32b", "train_4k", QWEN_VARIANTS, args.out)


if __name__ == "__main__":
    main()
