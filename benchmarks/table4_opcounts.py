"""Paper Table IV/V: op counts and data sizes of the four major functions."""

from __future__ import annotations

from benchmarks.common import bench_params, row
from benchmarks.opcount_model import (
    data_sizes, function_op_counts, np_for, plimbs_for,
)


def run(full: bool = False) -> None:
    params = bench_params(full)
    logq = params.logQ
    for region in (1, 2):
        npn = np_for(params, logq, region)
        pl = plimbs_for(params, npn)
        counts = function_op_counts(params.N, params.logN,
                                    params.qlimbs(logq), npn, pl)
        for fn, ops in counts.items():
            total = sum(ops.values())
            row(f"table4/r{region}/{fn}", total,
                f"mul={ops['mul']:.0f};modmul={ops['modmul']:.0f};"
                f"adc={ops['adc']:.0f};addsub={ops['addsub']:.0f}")
        sizes = data_sizes(params, logq, region)
        for k, v in sizes.items():
            row(f"table5/r{region}/{k}_words", v, f"{v*params.beta_bits//8}B")


if __name__ == "__main__":
    run()
