"""Paper Table I: message vs ciphertext op cost (addition, multiplication).

Messages: elementwise complex128 ops on n slots (numpy, per-element cost).
Ciphertexts: HE Add (limb adds + mask) and HE Mul (the Fig. 2 pipeline),
also reported per slot-element to match the paper's accounting.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_params, row, timeit
from repro.core import heaan as H
from repro.core.keys import keygen


def run(full: bool = False) -> None:
    params = bench_params(full)
    n = params.n_slots_max
    rng = np.random.default_rng(0)
    z1 = rng.normal(size=n) + 1j * rng.normal(size=n)
    z2 = rng.normal(size=n) + 1j * rng.normal(size=n)

    # message ops (per element)
    t0 = time.perf_counter()
    reps = 200
    for _ in range(reps):
        _ = z1 + z2
    t_madd = (time.perf_counter() - t0) / (reps * n)
    t0 = time.perf_counter()
    for _ in range(reps):
        _ = z1 * z2
    t_mmul = (time.perf_counter() - t0) / (reps * n)

    sk, pk, evk = keygen(params, seed=0)
    c1 = H.encrypt_message(z1, pk, params, seed=1)
    c2 = H.encrypt_message(z2, pk, params, seed=2)

    t_add, _ = timeit(H.he_add, c1, c2, reps=3)
    t_mul, _ = timeit(H.he_mul, c1, c2, evk, params, reps=1, warmup=1)

    row("table1/message_add_ns_per_elem", t_madd * 1e6,
        f"{t_madd*1e9:.2f}ns")
    row("table1/message_mul_ns_per_elem", t_mmul * 1e6,
        f"{t_mmul*1e9:.2f}ns")
    row("table1/he_add_us", t_add * 1e6,
        f"slowdown_vs_msg={t_add/(t_madd*n):.0f}x")
    row("table1/he_mul_us", t_mul * 1e6,
        f"slowdown_vs_msg={t_mul/(t_mmul*n):.0f}x "
        f"(paper: 36112x on 1 CPU thread)")
    row("table1/he_mul_over_he_add", t_mul / t_add * 1e6 / 1e6,
        f"{t_mul/t_add:.0f}x (paper: 448x)")


if __name__ == "__main__":
    run()
