"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # default params
    PYTHONPATH=src python -m benchmarks.run --full     # paper params (slow)
    PYTHONPATH=src python -m benchmarks.run --only table8

Prints ``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

import repro.core  # noqa: F401  (x64)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper parameters (logN=16, logQ=1200); slow")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (
        fig3_breakdown, fig67_scaling, roofline, table1_message_vs_cipher,
        table4_opcounts, table7_opt_ladder, table8_crt_strategies,
        table9_ntt_radix, table10_instr_model,
    )
    modules = [
        ("table1", table1_message_vs_cipher),
        ("fig3", fig3_breakdown),
        ("table4", table4_opcounts),
        ("table7", table7_opt_ladder),
        ("table8", table8_crt_strategies),
        ("table9", table9_ntt_radix),
        ("table10", table10_instr_model),
        ("fig67", fig67_scaling),
        ("roofline", roofline),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            mod.run(full=args.full)
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr, flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
