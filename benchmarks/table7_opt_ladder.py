"""Paper Table VII: the optimization ladder, Ref → optimized.

The paper's ladder (single-thread Ref → AVX-MT 42.9× → GPU-CLH 134.1×) maps
onto our pipeline-configuration ladder (same algorithmic steps, JAX/XLA on
this host's CPU):

  ref        naive iCRT (Algo 5, N-parallel) + per-iteration-modulo CRT —
             the reference HEAAN structure.
  vec        acc3 CRT + acc3 iCRT: wide accumulators + single fold (the
             AVX/GPU-C step).
  vec-m      + modified Shoup (3-half-mul mulhi, §V-B).
  reordered  + loop-reordered iCRT/CRT as integer matmuls (Algo 6 /
             AVX-MT / GPU-CL — the paper's key move).

Wall times are HE Mul end-to-end on this container's single CPU core; the
paper's absolute ratios need its 24-core AVX-512 / Titan RTX hardware, but
the ORDER and the source of each gain reproduce.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import bench_params, row, timeit
from repro.core import heaan as H
from repro.core.keys import keygen
from repro.core.rns import PipelineConfig

LADDER = [
    ("ref", PipelineConfig(crt_strategy="shoup", icrt_strategy="naive")),
    ("vec", PipelineConfig(crt_strategy="acc3", icrt_strategy="acc3")),
    ("vec-m", PipelineConfig(crt_strategy="acc3", icrt_strategy="acc3",
                             modified_shoup=True)),
    ("reordered", PipelineConfig(crt_strategy="matmul",
                                 icrt_strategy="matmul")),
]


def run(full: bool = False) -> None:
    params = bench_params(full)
    sk, pk, evk = keygen(params, seed=0)
    rng = np.random.default_rng(1)
    n = min(64, params.n_slots_max)
    z1 = rng.normal(size=n) + 1j * rng.normal(size=n)
    z2 = rng.normal(size=n) + 1j * rng.normal(size=n)
    c1 = H.encrypt_message(z1, pk, params, seed=2)
    c2 = H.encrypt_message(z2, pk, params, seed=3)

    base = None
    outs = {}
    for name, cfg in LADDER:
        t, ct = timeit(H.he_mul, c1, c2, evk, params, cfg, reps=1,
                       warmup=1)
        outs[name] = np.asarray(ct.ax)
        base = base or t
        row(f"table7/{name}_he_mul_ms", t * 1e6,
            f"speedup_vs_ref={base/t:.2f}x")
    for name in list(outs)[1:]:
        assert (outs[name] == outs["ref"]).all(), \
            f"{name} diverged from ref (correctness!)"
    row("table7/ladder_consistent", 0.0, "all rungs bitwise identical")


if __name__ == "__main__":
    run()
