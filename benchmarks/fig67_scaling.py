"""Paper Figs. 6 & 7: HE Mul op counts vs log Q (O((log Q)³)) and vs log q.

Fig. 6: at each log Q, N is scaled per the security table (Table II) and
np/qLimbs/PLimbs follow; total ops ∝ (log Q)³.
Fig. 7: at fixed log Q = max, ops vs the current level's log q; region-2 np
tracks (log q + 2 log Q), so cost at the last level stays ≳20 % of the top
(paper: 24 %).
"""

from __future__ import annotations

from benchmarks.common import bench_params, row
from benchmarks.opcount_model import hemul_total_ops
from repro.core.params import HEParams


# Table II: logQ -> logN for 80-bit security
_SECURITY = {150: 13, 300: 14, 600: 15, 1200: 16, 2400: 17}


def run(full: bool = False) -> None:
    base = None
    for logQ, logN in _SECURITY.items():
        p = HEParams(logN=logN, logQ=logQ, logp=30, log_delta=30,
                     beta_bits=32)
        ops = hemul_total_ops(p, logQ)
        base = base or ops
        row(f"fig6/logQ{logQ}", ops / 1e6,
            f"rel={ops/base:.2f}x N=2^{logN}")

    params = bench_params(full)
    top = hemul_total_ops(params, params.logQ)
    for frac in (1.0, 0.75, 0.5, 0.25, 30 / params.logQ):
        logq = max(params.logp, int(params.logQ * frac)
                   // params.logp * params.logp)
        ops = hemul_total_ops(params, logq)
        row(f"fig7/logq{logq}", ops / 1e6,
            f"rel_to_top={100*ops/top:.0f}% (paper: 24% at logq=30)")


if __name__ == "__main__":
    run()
