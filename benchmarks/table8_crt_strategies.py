"""Paper Table VIII: CRT delayed-modulo strategies.

GPU (paper): Mod1 0.89× < base < Mod2 1.43× < Mod4 1.98× < carry (GPU-C)
3.64×. Ours: per-iteration Shoup ("shoup" ≈ Mod1), remainder every 2/4
("mod2"/"mod4"), 3-word ADC accumulation ("acc3" = GPU-C), and the
beyond-paper integer-matmul form ("matmul").
"""

from __future__ import annotations

import random

import jax.numpy as jnp

from benchmarks.common import bench_params, row, timeit
from repro.core.context import make_context
from repro.core.crt import crt
from repro.nt.residue import ints_to_limb_array

STRATEGIES = ("shoup", "mod2", "mod4", "acc3", "matmul")


def run(full: bool = False) -> None:
    params = bench_params(full)
    ctx = make_context(params, params.logQ)
    g = ctx.tables
    npn, K, N = ctx.np2, ctx.qlimbs, ctx.N
    pr = random.Random(0)
    x = jnp.asarray(ints_to_limb_array(
        [pr.getrandbits(params.logQ) for _ in range(N)], K,
        params.beta_bits))
    args = (jnp.asarray(g.crt_tb[:npn, :K]),
            jnp.asarray(g.crt_tb_shoup[:npn, :K]),
            jnp.asarray(g.primes[:npn]))
    base = None
    for s in STRATEGIES:
        t, _ = timeit(lambda s=s: crt(x, *args, strategy=s), reps=3)
        base = base or t
        row(f"table8/crt_{s}", t * 1e6,
            f"speedup_vs_shoup={base/t:.2f}x (paper GPU-C: 3.64x)")


if __name__ == "__main__":
    run()
