"""Analytic operation-count model (paper Tables IV, V, X; Figs 6, 7).

Counts follow Table IV exactly:
  CRT : N·qLimbs·np mul + N·np modmul + N·qLimbs·np ADC
  NTT : np·(N/2)·logN modmul + np·N·logN add/sub
  iNTT: np·((N/2)·logN + N) modmul + np·N·logN add/sub
  iCRT: N·np·PLimbs mul + 2·N·np modmul + N·np·PLimbs ADC

Emulation costs (paper §V-B / Table X, our 16-bit-split TPU variant in
parentheses): a β-bit mul = 4 half-muls + 5 add + 5 shift; a Shoup modmul =
1 mulhi (4/3 half-muls) + 2 mullo + compare + sub; ADC = add + compare +
add. Native-instruction counts assume 1 instr per mul/modmul-step/ADC.
"""

from __future__ import annotations

import math
from typing import Dict

from repro.core.params import HEParams


def function_op_counts(N: int, logN: int, qlimbs: int, npn: int,
                       plimbs: int) -> Dict[str, Dict[str, float]]:
    return {
        "CRT": {
            "mul": N * qlimbs * npn,
            "modmul": N * npn,
            "adc": N * qlimbs * npn,
            "addsub": 0,
        },
        "NTT": {
            "mul": 0,
            "modmul": npn * (N // 2) * logN,
            "adc": 0,
            "addsub": npn * N * logN,
        },
        "iNTT": {
            "mul": 0,
            "modmul": npn * ((N // 2) * logN + N),
            "adc": 0,
            "addsub": npn * N * logN,
        },
        "iCRT": {
            "mul": N * npn * plimbs,
            "modmul": 2 * N * npn,
            "adc": N * npn * plimbs,
            "addsub": 0,
        },
    }


# instruction costs: (emulated_by_halfword_split, native)
_COST = {
    "mul": (14, 1),      # 4 half-muls + 5 add + 5 shift
    "modmul": (20, 3),   # Shoup: mulhi(4h)+... ≈ 4+5+5 + 2 mullo + cmp + sub
    "adc": (3, 1),       # add + cmp + add
    "addsub": (1, 1),
}


def instr_counts(counts: Dict[str, Dict[str, float]], native: bool
                 ) -> Dict[str, float]:
    idx = 1 if native else 0
    return {fn: sum(_COST[k][idx] * v for k, v in ops.items())
            for fn, ops in counts.items()}


def np_for(params: HEParams, logq: int, region: int) -> int:
    return (params.np_region1(logq) if region == 1
            else params.np_region2(logq))


def plimbs_for(params: HEParams, npn: int) -> int:
    bits = sum(math.log2(p) for p in params.primes[:npn])
    return params.limbs_for_bits(int(bits))


def hemul_op_counts(params: HEParams, logq: int) -> Dict[str, float]:
    """Total per-function counts over the full Fig. 2 HE Mul pipeline."""
    N, logN = params.N, params.logN
    K = params.qlimbs(logq)
    total: Dict[str, float] = {}
    for region, n_crt, n_ntt, n_intt, n_icrt in ((1, 4, 4, 3, 3),
                                                 (2, 1, 1, 2, 2)):
        npn = np_for(params, logq, region)
        pl = plimbs_for(params, npn)
        per = function_op_counts(N, logN, K, npn, pl)
        w = {"CRT": n_crt, "NTT": n_ntt, "iNTT": n_intt, "iCRT": n_icrt}
        for fn, ops in per.items():
            for k, v in ops.items():
                total[f"{fn}/{k}"] = total.get(f"{fn}/{k}", 0) + w[fn] * v
    return total


def hemul_total_ops(params: HEParams, logq: int) -> float:
    return sum(hemul_op_counts(params, logq).values())


def data_sizes(params: HEParams, logq: int, region: int) -> Dict[str, int]:
    """Paper Table V (in units of β words)."""
    N = params.N
    K = params.qlimbs(logq)
    npn = np_for(params, logq, region)
    pl = plimbs_for(params, npn)
    return {
        "CRT_input": N * K,
        "CRT_table": npn * K,
        "NTT_input": N * npn,
        "NTT_table": N * npn,
        "iCRT_input": N * npn,
        "iCRT_table": npn + npn * pl,
    }
