"""Steady-state HE serving benchmark over the repro.hserve runtime.

Drives HEServer with a mixed mul/rotate request stream at paper-shaped
parameters and emits BENCH_serve_he.json — the repo's serving perf
trajectory: steady-state mul/s and rotate/s, p50/p99 request latency,
padding fraction, the resident table-cache footprint, plus (this PR's
additions, schema documented in docs/SERVING.md):

  - "trickle": p50/p99 request latency when the arrival rate is BELOW
    the batch size and only the age-based flush policy (max_age_s) gets
    requests served at all — the continuous-batching SLO path;
  - "overlap": drain wall time for the same mul stream with the
    double-buffered host↔device pipeline off vs on, and the speedup;
  - "plain": steady-state mul_plain/add_plain throughput — the
    plaintext-operand ops (encode-only operand, region 1 only, NO key
    switch) encrypted-inference affine layers ride;
  - "scheduler": the circuit-aware scheduler A/B — two degree-4
    circuits submitted one engine batch out of phase, drained with
    scheduling off vs on: cross-circuit co-batch rate, mul padding
    fraction, deferral/prefetch counts, and a bitwise-identical guard
    (scheduling must never change a result bit);
  - "client": the repro.client traced-session A/B — the same
    (x·w)·x + x circuit submitted as hand-built CircuitOp lists (one
    client-side encode of w PER circuit) vs. traced handles through
    HESession.run (w encodes once; later circuits ship hash-only and
    hit the server's (hash, level) plaintext cache): drain walls,
    mul pad fraction, cross-circuit co-batch rate, cache hit rate, and
    a bitwise-identical guard (the frontend must never change a bit);
  - "analysis": the repro.analysis cost-model A/B — the scheduler's
    deferral gate consulting a CostModel calibrated from THIS record's
    own throughputs vs deferring unconditionally, on the same staggered
    degree-4 pair: drain walls, batch counts, mul padding, deferral /
    cost-skip counts, the model's estimated device-seconds per circuit,
    and a bitwise-identical guard (cost-gated scheduling must never
    change a result bit);
  - "boot": the repro.boot batched-bootstrapping A/B — one bootstrap
    per drain vs two concurrent pipelines co-draining on the reference
    small-param bootstrap config: per-bootstrap latency, cross-circuit
    co-batch rate (> 0 is gated by check_docs — the batched payoff),
    and the error contract (max_err ≤ the documented plan bound,
    precision_bits in/out — bootstrap is approximate, never bitwise);
  - "obs": the repro.obs tracing overhead A/B — the same mul stream
    drained with the request-lifecycle Tracer detached vs attached,
    interleaved min-of-3: drain walls, overhead fraction (gated ≤2% by
    tools/check_docs.py — always-on tracing must be production-safe),
    trace event count, and a bitwise-identical guard.

    PYTHONPATH=src python benchmarks/serve_he.py                # quick
    PYTHONPATH=src python benchmarks/serve_he.py --full         # Table III
    PYTHONPATH=src python benchmarks/serve_he.py --logn 14 --logq 600

Request payloads reuse a small pool of pre-encrypted ciphertexts (setup
cost), so the measured loop is exactly the serving path: queue → batch
assembly → resident-table engine step → result wrap. A warm-up pass
compiles every (op, level) signature and the metrics window is reset
before the measured stream, so BOTH throughput and latency percentiles
are steady state (compile time is reported separately).
"""

from __future__ import annotations

import argparse
import json
import math
import sys
import time


def run(params, *, batch: int, mul_requests: int, rot_requests: int,
        levels: int, model_shards: int, use_kernels: bool,
        trickle_requests: int = 6, trickle_max_age_s: float = 0.02,
        overlap_muls: int = 0) -> dict:
    import numpy as np

    from repro.core import heaan as H
    from repro.core.keys import keygen
    from repro.core.rotate import conj_keygen, rot_keygen
    from repro.hserve import HEServer, degree4_demo_circuit
    from repro.launch.mesh import make_host_mesh

    t0 = time.perf_counter()
    sk, pk, evk = keygen(params, seed=0)
    rot_keys = {1: rot_keygen(params, sk, 1)} if rot_requests else {}
    conj_key = conj_keygen(params, sk)    # the degree-4 scheduler A/B
    keygen_s = time.perf_counter() - t0

    server = HEServer(params, evk, rot_keys, conj_key,
                      mesh=make_host_mesh(model=model_shards),
                      batch=batch, use_kernels=use_kernels)

    # a small ciphertext pool; requests cycle through it
    rng = np.random.default_rng(0)
    n = params.n_slots_max
    t0 = time.perf_counter()
    pool = [H.encrypt_message(
        rng.normal(size=n) + 1j * rng.normal(size=n), pk, params,
        seed=i + 1) for i in range(min(4, 2 * batch))]
    logqs = [params.logQ - i * params.logp for i in range(levels)]
    by_level = {
        lq: [c if lq == params.logQ else H.he_mod_down(c, params, lq)
             for c in pool] for lq in logqs}
    encrypt_s = time.perf_counter() - t0

    # warm-up: compile every (op, level) signature the stream will hit,
    # then reset the measurement window — reported latency/throughput
    # are steady state (compile_s is reported separately)
    for i in range(levels):
        cs = by_level[logqs[i]]
        if mul_requests:
            server.submit_mul(cs[0], cs[1 % len(cs)])
        if rot_requests:
            server.submit_rotate(cs[0], 1)
    server.drain()
    server.reset_metrics()

    for i in range(mul_requests):
        cs = by_level[logqs[i % levels]]
        server.submit_mul(cs[i % len(cs)], cs[(i + 1) % len(cs)])
    for i in range(rot_requests):
        cs = by_level[logqs[i % levels]]
        server.submit_rotate(cs[i % len(cs)], 1)

    t0 = time.perf_counter()
    results = server.drain()
    drain_s = time.perf_counter() - t0

    stats = server.stats()
    per_op = stats["per_op"]

    # ---- overlap on/off: same mul stream, double buffering toggled ------
    overlap_muls = overlap_muls or 2 * batch * max(1, levels)
    top = by_level[params.logQ]

    def overlap_drain(on: bool) -> float:
        server.overlap = on
        for i in range(overlap_muls):
            cs = by_level[logqs[i % levels]]
            server.submit_mul(cs[i % len(cs)], cs[(i + 1) % len(cs)])
        t0 = time.perf_counter()
        server.drain()
        return time.perf_counter() - t0

    off_s = overlap_drain(False)
    on_s = overlap_drain(True)
    server.overlap = False

    # ---- plaintext-operand ops: region-1-only throughput ----------------
    server.reset_metrics()
    plain_requests = 2 * batch
    pts = [H.encode_plain(
        np.asarray(rng.normal(size=n) + 1j * rng.normal(size=n)),
        params, params.logQ) for _ in range(2)]
    for i in range(plain_requests):
        ct = top[i % len(top)]
        server.submit_mul_plain(ct, pts[i % 2])
        server.submit_add_plain(ct, pts[i % 2])
    server.drain()
    pl = server.stats()["per_op"]

    # ---- scheduler A/B: two degree-4 circuits, one batch out of phase --
    ops4, _ = degree4_demo_circuit(params)

    def staggered_circuits(schedule: bool):
        server.schedule = schedule
        server.reset_metrics()    # new window (zeroes scheduler counters)
        # baseline AFTER the reset, so the deltas stay per-phase even if
        # reset_metrics ever stops zeroing the scheduler counters
        d0, p0 = server.scheduler.deferrals, server.scheduler.prefetches
        res = {}
        c1 = server.submit_circuit(ops4, {"x": top[0]})
        res.update(dict(server.poll(flush=True)))   # desync the pair
        c2 = server.submit_circuit(ops4, {"x": top[1 % len(top)]})
        t0 = time.perf_counter()
        res.update(server.drain())
        wall = time.perf_counter() - t0
        s = server.stats()
        return {
            "drain_s": round(wall, 4),
            "batches": sum(d["batches"] for d in s["per_op"].values()),
            "mul_pad_frac": s["per_op"]["mul"]["pad_frac"],
            "cross_circuit_batches":
                s["cobatch"]["cross_circuit_batches"],
            "cross_circuit_rate": s["cobatch"]["cross_circuit_rate"],
            "deferrals": server.scheduler.deferrals - d0,
            "prefetches": server.scheduler.prefetches - p0,
        }, (res[c1], res[c2])

    # warm pass runs SCHEDULED on the cold circuit levels, so the table
    # prefetches it reports are the real cold-cache ones (hidden behind
    # in-flight batches); the timed A/B that follows is fully warm
    warm, _ = staggered_circuits(True)
    unsched, outs_u = staggered_circuits(False)
    sched, outs_s = staggered_circuits(True)
    server.schedule = False
    sched["prefetches_cold"] = warm["prefetches"]
    bitwise = all(
        bool((np.asarray(a.ax) == np.asarray(b.ax)).all()
             and (np.asarray(a.bx) == np.asarray(b.bx)).all())
        for a, b in zip(outs_u, outs_s))
    assert bitwise, "scheduling changed a result bit"

    # ---- client: traced session vs hand-built circuits -----------------
    from repro.client import HESession
    from repro.core.encoding import message_hash
    from repro.hserve import CircuitOp

    session = HESession(params, sk=sk, pk=pk, evk=evk, server=server)
    k = max(2, min(4, len(top)))
    wz = rng.normal(size=n) + 1j * rng.normal(size=n)
    lq1, lq2 = params.logQ - params.logp, params.logQ - 2 * params.logp

    def hand_ops():
        # what PR-4 clients wrote by hand for (x·w)·x + x: explicit
        # level management, integer node refs, and a fresh client-side
        # encode of w for EVERY circuit
        pt = np.asarray(H.encode_plain(wz, params, params.logQ))
        return [
            CircuitOp("mul_plain", ("in0",), pt=pt,
                      pt_logp=params.log_delta),
            CircuitOp("rescale", (0,), dlogp=params.logp),
            CircuitOp("mod_down", ("in0",), logq2=lq1),
            CircuitOp("mul", (1, 2)),
            CircuitOp("rescale", (3,), dlogp=params.logp),
            CircuitOp("mod_down", ("in0",), logq2=lq2),
            CircuitOp("add", (4, 5)),
        ]

    # warm pass compiles the circuit's (op, level) signatures so BOTH
    # phases are steady state (same methodology as the main stream)
    server.submit_circuit(hand_ops(), {"in0": top[0]})
    server.drain()

    server.reset_metrics()
    t0 = time.perf_counter()
    hand_cids = [server.submit_circuit(hand_ops(),
                                       {"in0": top[i % len(top)]})
                 for i in range(k)]
    hand_res = server.drain()
    hand_s = time.perf_counter() - t0
    hand_stats = server.stats()

    server.reset_metrics()
    h0, m0 = server.cache.plain_hits, server.cache.plain_misses
    t0 = time.perf_counter()
    exprs = []
    for i in range(k):
        x = session.input(top[i % len(top)])
        exprs.append((x * wz) * x + x)
    tfuts = session.run(exprs)          # w encodes ONCE; rest hash-only
    session.drain()
    traced_s = time.perf_counter() - t0
    tr_stats = server.stats()
    hits = server.cache.plain_hits - h0
    total = hits + server.cache.plain_misses - m0
    client_bitwise = all(
        bool((np.asarray(hand_res[c].ax) == np.asarray(f.result().ax))
             .all()
             and (np.asarray(hand_res[c].bx)
                  == np.asarray(f.result().bx)).all())
        for c, f in zip(hand_cids, tfuts))
    assert client_bitwise, "the traced frontend changed a result bit"

    # ---- analysis: cost-model-gated scheduler A/B -----------------------
    # calibrate repro.analysis.CostModel from the throughputs measured
    # ABOVE (the record being emitted is its own calibration source),
    # then drain the same staggered degree-4 pair with the scheduler's
    # deferral gate consulting the model vs not. At serving params a
    # full-depth mul bucket clears defer_min_s (defer: co-batching
    # pays) while add/rescale buckets cost ~µs (cost_skips: flush now)
    from repro.analysis import CostModel

    cm = CostModel.from_bench({
        "params": {"logN": params.logN, "logQ": params.logQ,
                   "logp": params.logp, "beta_bits": params.beta_bits},
        "levels": logqs,
        "mul_per_s": per_op.get("mul", {}).get("ops_per_s", 0.0),
        "rotate_per_s": per_op.get("rotate", {}).get("ops_per_s", 0.0),
        "plain": {"mul_plain_per_s": pl["mul_plain"]["ops_per_s"],
                  "add_plain_per_s": pl["add_plain"]["ops_per_s"]},
    }, params=params)
    est_s, _ = cm.estimate_circuit(ops4, {"x": (params.logQ, params.logp)})

    def costed_circuits(cost_model):
        server.schedule = True
        server.scheduler.cost_model = cost_model
        server.reset_metrics()
        d0 = server.scheduler.deferrals
        k0 = server.scheduler.cost_skips
        res = {}
        c1 = server.submit_circuit(ops4, {"x": top[0]})
        res.update(dict(server.poll(flush=True)))   # desync the pair
        c2 = server.submit_circuit(ops4, {"x": top[1 % len(top)]})
        t0 = time.perf_counter()
        res.update(server.drain())
        wall = time.perf_counter() - t0
        s = server.stats()
        return {
            "drain_s": round(wall, 4),
            "batches": sum(d["batches"] for d in s["per_op"].values()),
            "mul_pad_frac": s["per_op"]["mul"]["pad_frac"],
            "deferrals": server.scheduler.deferrals - d0,
            "cost_skips": server.scheduler.cost_skips - k0,
        }, (res[c1], res[c2])

    nocost, outs_n = costed_circuits(None)
    withcost, outs_c = costed_circuits(cm)
    server.schedule = False
    server.scheduler.cost_model = None
    an_bitwise = all(
        bool((np.asarray(a.ax) == np.asarray(b.ax)).all()
             and (np.asarray(a.bx) == np.asarray(b.bx)).all())
        for a, b in zip(outs_n, outs_c))
    assert an_bitwise, "cost-model scheduling changed a result bit"

    # ---- obs: lifecycle-tracing overhead A/B ----------------------------
    # the same mul stream drained with the repro.obs Tracer detached vs
    # attached (every submit/flush/dispatch/complete event recorded).
    # Interleaved min-of-3 so one GC pause or turbo transition cannot
    # poison either arm; the gate (tools/check_docs.py OBS_SCHEMA) is
    # ≤2% overhead and bitwise-identical results — always-on tracing
    # must be safe to leave enabled in production serving.
    from repro.obs import Tracer

    obs_muls = overlap_muls

    def obs_drain(tracer):
        server.tracer = tracer
        for i in range(obs_muls):
            cs = by_level[logqs[i % levels]]
            server.submit_mul(cs[i % len(cs)], cs[(i + 1) % len(cs)])
        t0 = time.perf_counter()
        res = server.drain()
        server.tracer = None
        return time.perf_counter() - t0, [res[r] for r in sorted(res)]

    off_walls, on_walls = [], []
    trace_events = 0
    obs_bitwise = True
    for _ in range(3):
        w_off, outs_off = obs_drain(None)
        tr_on = Tracer()
        w_on, outs_on = obs_drain(tr_on)
        off_walls.append(w_off)
        on_walls.append(w_on)
        trace_events = len(tr_on)
        obs_bitwise &= all(
            bool((np.asarray(a.ax) == np.asarray(b.ax)).all()
                 and (np.asarray(a.bx) == np.asarray(b.bx)).all())
            for a, b in zip(outs_off, outs_on))
    assert obs_bitwise, "tracing changed a result bit"
    obs_off_s, obs_on_s = min(off_walls), min(on_walls)

    # ---- multihost: frontend/worker scaling + worker-death requeue ------
    # the same mul stream served through the disaggregated tier
    # (HEFrontend routing batches to W in-process worker engines) for
    # W in 1/2/4. All workers share this host's devices, so wall time
    # cannot scale here; the scaling signal is VIRTUAL time — each
    # worker's busy_s is the device-seconds it actually computed, and
    # makespan_W = max_w busy_s models W hosts running concurrently.
    # efficiency(W) = busy_total(1) / (W · makespan_W): 1.0 is perfect
    # load balance, < 0.7 at W=4 fails the check_docs gate. A second
    # pass kills one worker mid-batch via the FailureInjector and
    # verifies the requeue path re-serves bitwise identically.
    from repro.hserve import HEFrontend
    from repro.runtime.failures import FailureInjector

    mh_muls = 8 * batch

    def mh_submit(srv):
        rids = []
        for i in range(mh_muls):
            cs = by_level[logqs[i % levels]]
            rids.append(srv.submit_mul(cs[i % len(cs)],
                                       cs[(i + 1) % len(cs)]))
        return rids

    ref_rids = mh_submit(server)
    ref_res = server.drain()
    ref_outs = [ref_res[r] for r in ref_rids]

    def mh_bitwise_vs_ref(rids, res):
        return all(
            bool((np.asarray(a.ax) == np.asarray(res[r].ax)).all()
                 and (np.asarray(a.bx) == np.asarray(res[r].bx)).all())
            for a, r in zip(ref_outs, rids))

    mesh = make_host_mesh(model=model_shards)
    per_workers = {}
    mh_bitwise = True
    for W in (1, 2, 4):
        fe = HEFrontend(params, evk, mesh=mesh, batch=batch, workers=W)
        # warm every worker on every (mul, level) signature (W batches
        # per level spread over the W idle workers), then zero busy_s —
        # the measured sweep is steady state, like the monolith's
        for lq in logqs:
            cs = by_level[lq]
            for i in range(W * batch):
                fe.submit_mul(cs[i % len(cs)], cs[(i + 1) % len(cs)])
        fe.drain()
        fe.reset_metrics()
        rids = mh_submit(fe)
        res = fe.drain()
        mh_bitwise &= mh_bitwise_vs_ref(rids, res)
        busy = [w.busy_s for w in fe.workers]
        makespan = max(busy)
        per_workers[str(W)] = {
            "busy_s": round(sum(busy), 4),
            "makespan_s": round(makespan, 4),
            "mul_per_s": round(mh_muls / makespan, 3) if makespan else 0.0,
        }
        fe.close()
    assert mh_bitwise, "multi-host serving changed a result bit"
    busy_1 = per_workers["1"]["busy_s"]
    mh_eff4 = round(busy_1 / (4 * per_workers["4"]["makespan_s"]), 3) \
        if per_workers["4"]["makespan_s"] else 0.0

    # requeue A/B: worker 0 dies right after its second dispatch (the
    # batch is computed but never delivered); the frontend must detect
    # the death, requeue the in-flight requests, and re-serve them on
    # the surviving worker — bitwise identically
    fe = HEFrontend(params, evk, mesh=mesh, batch=batch, workers=2,
                    injector=FailureInjector(kill_worker_at={0: 2}))
    rids = mh_submit(fe)
    res = fe.drain()
    rq_bitwise = mh_bitwise_vs_ref(rids, res)
    assert rq_bitwise, "worker-death requeue changed a result bit"
    fr = fe.stats()["frontend"]
    fe.close()

    # ---- boot: the batched-bootstrapping A/B -----------------------------
    # served CKKS bootstrapping (repro.boot) on its OWN server at the
    # reference small-param config (the pipeline needs logQ = 14·logp,
    # independent of this record's params). A = one bootstrap per
    # drain; B = two concurrent bootstraps in one drain — the batched
    # payoff is the circuit scheduler co-batching their aligned
    # rotation/mul stages ACROSS the two pipelines (cross_circuit_rate
    # > 0 is gated by tools/check_docs.py, as is the error contract:
    # bootstrap is approximate, max_err must stay ≤ the documented
    # plan.error_bound()).
    from repro.boot import boot_params, bootstrap_circuit

    bp = boot_params()
    bsk, bpk, bevk = keygen(bp, seed=0)
    brot = {r: rot_keygen(bp, bsk, r) for r in (1, 2, 3, 4)}
    bsrv = HEServer(bp, bevk, brot, conj_keygen(bp, bsk),
                    mesh=make_host_mesh(), batch=batch, schedule=True)
    plan = bootstrap_circuit(bp, logq_in=bp.logp,
                             plain_lookup=bsrv.cache.has_plain)
    brng = np.random.default_rng(99)
    bn = bp.n_slots_max

    def bmsg():
        z = brng.uniform(-1, 1, bn) + 1j * brng.uniform(-1, 1, bn)
        return z * (plan.msg_bound / np.max(np.abs(z)))

    bmsgs = [bmsg() for _ in range(2)]
    bcts = [H.he_mod_down(H.encrypt_message(z, bpk, bp, seed=200 + i),
                          bp, bp.logp) for i, z in enumerate(bmsgs)]
    err_in = max(float(np.max(np.abs(
        H.decrypt_message(ct, bsk, bp) - z)))
        for ct, z in zip(bcts, bmsgs))

    # warm-up bootstrap compiles every pipeline (op, level) cell
    bsrv.submit_bootstrap(bcts[0], plan=plan)
    bsrv.drain()
    boot_compile_s = bsrv.engine.compile_s

    bsrv.reset_metrics()                      # A: solo
    t0 = time.perf_counter()
    bsrv.submit_bootstrap(bcts[0], plan=plan)
    bsrv.drain()
    solo_s = time.perf_counter() - t0

    bsrv.reset_metrics()                      # B: 2 concurrent
    t0 = time.perf_counter()
    bcids = [bsrv.submit_bootstrap(ct, plan=plan) for ct in bcts]
    bres = bsrv.drain()
    pair_s = time.perf_counter() - t0
    bcb = bsrv.stats()["cobatch"]
    bouts = [bres[c] for c in bcids]
    err_out = max(float(np.max(np.abs(
        H.decrypt_message(o, bsk, bp) - z)))
        for o, z in zip(bouts, bmsgs))
    assert err_out <= plan.error_bound(), \
        f"bootstrap error {err_out:.3e} breached the documented " \
        f"bound {plan.error_bound():.3e}"
    assert all(o.logq == plan.out_logq for o in bouts)

    # ---- trickle: arrival rate < batch; only the age policy flushes.
    # adaptive_target is disabled here on purpose: with it on, a trickle
    # is released the moment the target shrinks to the arrival rate and
    # the age deadline never fires — this phase isolates the SLO path
    # (age_flushes == trickle_requests when it works).
    server.max_age_s = trickle_max_age_s
    server.adaptive_target = False
    server.reset_metrics()
    for i in range(trickle_requests):
        server.submit_mul(top[i % len(top)], top[(i + 1) % len(top)])
        while not server.poll():          # poll until the age deadline
            time.sleep(trickle_max_age_s / 10)   # fires (no full bucket)
    tr = server.stats()
    server.max_age_s = None
    server.adaptive_target = True
    return {
        "params": {"logN": params.logN, "logQ": params.logQ,
                   "logp": params.logp, "beta_bits": params.beta_bits,
                   "np1_top": params.np_region1(params.logQ),
                   "np2_top": params.np_region2(params.logQ)},
        "batch": batch,
        "levels": logqs,
        "use_kernels": use_kernels,
        "mesh": stats["mesh"],
        "requests": {"mul": mul_requests, "rotate": rot_requests,
                     "completed": len(results)},
        "mul_per_s": per_op.get("mul", {}).get("ops_per_s", 0.0),
        "rotate_per_s": per_op.get("rotate", {}).get("ops_per_s", 0.0),
        "latency_ms": {
            op: per_op[op]["latency_ms"] for op in per_op},
        "pad_frac": {op: per_op[op]["pad_frac"] for op in per_op},
        "queue_depth": stats["queue_depth"],
        "cache": stats["cache"],
        "compile_s": stats["engine"]["compile_s"],
        "steps_compiled": stats["engine"]["steps_compiled"],
        "setup_s": {"keygen": round(keygen_s, 3),
                    "encrypt_pool": round(encrypt_s, 3)},
        "drain_wall_s": round(drain_s, 3),
        "trickle": {
            "requests": trickle_requests,
            "max_age_s": trickle_max_age_s,
            "p50_ms": tr["per_op"]["mul"]["latency_ms"]["p50"],
            "p99_ms": tr["per_op"]["mul"]["latency_ms"]["p99"],
            "age_flushes": tr["flushes"]["age"],
        },
        "overlap": {
            "muls": overlap_muls,
            "off_drain_s": round(off_s, 4),
            "on_drain_s": round(on_s, 4),
            "speedup": round(off_s / on_s, 3) if on_s > 0 else 0.0,
        },
        "plain": {
            "requests": 2 * plain_requests,
            "mul_plain_per_s": pl["mul_plain"]["ops_per_s"],
            "add_plain_per_s": pl["add_plain"]["ops_per_s"],
            "mul_plain_vs_mul": round(
                pl["mul_plain"]["ops_per_s"]
                / per_op["mul"]["ops_per_s"], 3)
            if per_op.get("mul", {}).get("ops_per_s") else 0.0,
        },
        "scheduler": {
            "circuits": 2,
            "lookahead": server.scheduler.lookahead,
            "unscheduled": unsched,
            "scheduled": sched,
            "bitwise_identical": bitwise,
        },
        "client": {
            "circuits": k,
            "hand_drain_s": round(hand_s, 4),
            "traced_drain_s": round(traced_s, 4),
            "hand_mul_pad_frac":
                hand_stats["per_op"]["mul"]["pad_frac"],
            "traced_mul_pad_frac":
                tr_stats["per_op"]["mul"]["pad_frac"],
            "cross_circuit_rate":
                tr_stats["cobatch"]["cross_circuit_rate"],
            "plain_cache_hits": hits,
            "plain_cache_hit_rate":
                round(hits / total, 3) if total else 0.0,
            "bitwise_identical": client_bitwise,
        },
        "analysis": {
            "circuits": 2,
            "calibrated_from": "self",
            "est_circuit_s": round(est_s, 6),
            "nocost": nocost,
            "cost": withcost,
            "bitwise_identical": an_bitwise,
        },
        "obs": {
            "muls": obs_muls,
            "off_drain_s": round(obs_off_s, 4),
            "on_drain_s": round(obs_on_s, 4),
            "overhead_frac": round(obs_on_s / obs_off_s - 1.0, 4),
            "trace_events": trace_events,
            "bitwise_identical": obs_bitwise,
        },
        "boot": {
            "params": {"logN": bp.logN, "logQ": bp.logQ,
                       "logp": bp.logp},
            "concurrent": 2,
            "pipeline_ops": len(plan.ops),
            "logq_in": plan.logq_in,
            "out_logq": plan.out_logq,
            "levels_gained": plan.levels_gained,
            "compile_s": round(boot_compile_s, 3),
            "solo_latency_s": round(solo_s, 4),
            "concurrent_drain_s": round(pair_s, 4),
            "latency_s_per_bootstrap": round(pair_s / 2, 4),
            "cobatch_speedup": round(2 * solo_s / pair_s, 3)
            if pair_s > 0 else 0.0,
            "cross_circuit_batches": bcb["cross_circuit_batches"],
            "cross_circuit_rate": bcb["cross_circuit_rate"],
            "max_err": err_out,
            "error_bound": plan.error_bound(),
            "precision_bits_in": round(-math.log2(err_in), 2)
            if err_in > 0 else float(bp.logp),
            "precision_bits_out": round(-math.log2(err_out), 2)
            if err_out > 0 else float(bp.logp),
        },
        "multihost": {
            "muls": mh_muls,
            "batch": batch,
            "transport": "inproc",
            "workers_swept": [1, 2, 4],
            "per_workers": per_workers,
            "scaling_efficiency_at_4": mh_eff4,
            "requeue": {
                "worker_deaths": fr["deaths"],
                "requeued_requests": fr["requeued_requests"],
                "bitwise_identical": rq_bitwise,
            },
            "bitwise_identical": mh_bitwise,
        },
    }


def main(argv=None):
    from repro.core.params import HEParams

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true",
                    help="paper Table III params (logN=16, logQ=1200) — "
                         "hours on CPU; the TPU target's configuration")
    ap.add_argument("--logn", type=int, default=8)
    ap.add_argument("--logq", type=int, default=240)
    ap.add_argument("--logp", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--muls", type=int, default=12)
    ap.add_argument("--rotations", type=int, default=8)
    ap.add_argument("--levels", type=int, default=2)
    ap.add_argument("--model-shards", type=int, default=1)
    ap.add_argument("--kernels", action="store_true")
    ap.add_argument("--out", default="BENCH_serve_he.json")
    args = ap.parse_args(argv)

    if args.full:
        params = HEParams(logN=16, logQ=1200, logp=30, log_delta=30,
                          beta_bits=32)
    else:
        params = HEParams(logN=args.logn, logQ=args.logq, logp=args.logp,
                          log_delta=args.logp, beta_bits=32,
                          h=min(64, (1 << args.logn) // 2))

    out = run(params, batch=args.batch, mul_requests=args.muls,
              rot_requests=args.rotations, levels=args.levels,
              model_shards=args.model_shards, use_kernels=args.kernels)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(json.dumps(out, indent=2))
    print(f"\nwrote {args.out}", file=sys.stderr)


if __name__ == "__main__":
    main()
