"""Paper Table IX: NTT radix sweep → HBM-traffic model + measured stages.

On GPU the radix sets how many stages run per shared-memory residency:
HBM round trips = ceil(log2N / log2(radix)); paper measures 2.35×/2.09×
(NTT/iNTT) at radix-16/32 over radix-2.

On TPU the whole (1, N) row fits VMEM, so the Pallas kernel is single-pass
("radix-N"): the table reports the modeled HBM bytes per transform for each
radix and the measured per-stage cost of our in-VMEM pipeline. The derived
column shows traffic relative to radix-2 — at radix-N it is exactly
1/log₂N: the paper's optimization direction, taken to its limit.
"""

from __future__ import annotations

import math

import numpy as np
import jax.numpy as jnp

from benchmarks.common import bench_params, row, timeit
from repro.core.context import make_context
from repro.core.ntt import intt, ntt


def run(full: bool = False) -> None:
    params = bench_params(full)
    ctx = make_context(params, params.logQ)
    g = ctx.tables
    npn, N, logN = ctx.np2, ctx.N, params.logN
    word = params.beta_bits // 8
    base_bytes = 2 * npn * N * word          # one read + one write pass

    for radix in (2, 4, 16, 32, N):
        passes = math.ceil(logN / math.log2(radix))
        name = f"radix{radix}" if radix != N else "radixN_vmem_resident"
        row(f"table9/{name}_hbm_MB", passes * base_bytes / 1e6,
            f"passes={passes} rel_traffic={passes/logN:.3f} "
            f"(radix2=1.0)")

    rng = np.random.default_rng(0)
    primes = np.asarray(g.primes[:npn]).astype(np.uint64)
    x = jnp.asarray((rng.integers(0, 1 << 62, size=(npn, N))
                     .astype(np.uint64) % primes[:, None])
                    .astype(g.primes.dtype))
    t_f, ev = timeit(lambda: ntt(x, jnp.asarray(g.psi_rev[:npn]),
                                 jnp.asarray(g.psi_rev_shoup[:npn]),
                                 jnp.asarray(g.primes[:npn])), reps=3)
    t_i, _ = timeit(lambda: intt(ev, jnp.asarray(g.ipsi_rev[:npn]),
                                 jnp.asarray(g.ipsi_rev_shoup[:npn]),
                                 jnp.asarray(g.n_inv[:npn]),
                                 jnp.asarray(g.n_inv_shoup[:npn]),
                                 jnp.asarray(g.primes[:npn])), reps=3)
    row("table9/ntt_measured", t_f * 1e6,
        f"{npn}x{N}-point, {logN} stages")
    row("table9/intt_measured", t_i * 1e6,
        f"iNTT/NTT={t_i/t_f:.2f} (paper: ~1.1-1.25, extra /N pass)")


if __name__ == "__main__":
    run()
