"""Paper Table X: emulated vs native instruction counts per function.

The paper counts AVX-512 instructions with 64-bit mul/modmul/ADC emulated
vs hypothetically native. Our TPU adaptation synthesizes 32-bit ops from
16-bit halves — the same analysis with the same conclusion: CRT and iCRT
would shrink to ~16-18 % of their instruction streams with native widening
multiply + carry, NTT/iNTT to ~a third.
"""

from __future__ import annotations

from benchmarks.common import bench_params, row
from benchmarks.opcount_model import (
    function_op_counts, instr_counts, np_for, plimbs_for,
)


def run(full: bool = False) -> None:
    params = bench_params(full)
    logq = params.logQ
    npn = np_for(params, logq, 2)
    pl = plimbs_for(params, npn)
    counts = function_op_counts(params.N, params.logN, params.qlimbs(logq),
                                npn, pl)
    emu = instr_counts(counts, native=False)
    nat = instr_counts(counts, native=True)
    for fn in counts:
        row(f"table10/{fn}/emulated_Minstr", emu[fn] / 1e6,
            f"native={nat[fn]/1e6:.0f}M "
            f"ratio={100*nat[fn]/emu[fn]:.1f}% "
            "(paper: CRT 17.3%, iCRT 15.8%, NTT/iNTT ~33%)")


if __name__ == "__main__":
    run()
